"""Failure handling for the trie serve loop: clocks, retry/backoff,
shard health, and the backend-demotion ladder.

The scheduler (``serve.scheduler``) stays a pure queueing/batching loop;
everything that decides *where* and *whether to try again* lives here:

* ``VirtualClock`` / ``MonotonicClock`` — one tiny clock seam so the
  whole serve stack runs as a deterministic discrete-event simulation
  under test (and in the bench's virtual-arrival replay) while serving
  real traffic off ``time.monotonic``.
* ``RetryPolicy`` + ``retry_call`` — exponential backoff with
  deterministic seeded jitter around TRANSIENT backend failures
  (``kernels.ops.is_retryable`` is the classifier; invalid queries and
  ``ShardFailure`` never burn retries — retrying the same dead shard or
  the same bad input cannot succeed).
* ``ShardHealth`` — per-shard failure counting plus slow-shard detection
  via the SAME ``StragglerDetector`` EWMA that training elasticity uses
  (``distributed.health``), feeding the demotion ladder.
* ``ResilientTrieEngine`` — wraps a primary ``TrieQueryEngine`` and, on
  per-shard failure, demotes WITHOUT dropping the in-flight batch:
  sharded → replicated (bit-identical answers, ``degraded=False``) →
  dead-shard-masked degraded plan (``trie_sharding.mask_dead_shards``,
  partial answers flagged ``degraded=True``).  The failing call is
  re-executed on the demoted backend inside the same ``query()`` call.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.distributed.health import StragglerDetector
from repro.kernels.ops import is_retryable


# ----------------------------------------------------------------------
# clocks (the determinism seam)
# ----------------------------------------------------------------------
class MonotonicClock:
    """Real time: ``now`` is ``time.monotonic``, ``sleep`` really sleeps."""

    def now(self) -> float:
        return time.monotonic()

    def sleep(self, seconds: float) -> None:
        if seconds > 0:
            time.sleep(seconds)


class VirtualClock:
    """Discrete-event time: ``sleep`` advances instantly.

    Tests and the bench's arrival replay drive deadlines, backoff
    schedules, and latency accounting through this — every run is
    bit-reproducible because nothing waits on the host."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def sleep(self, seconds: float) -> None:
        self._t += max(float(seconds), 0.0)

    advance = sleep


# ----------------------------------------------------------------------
# retry with exponential backoff + deterministic jitter
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """``base_ms * multiplier**attempt`` plus uniform jitter in
    ``[0, jitter_frac * raw)`` drawn from a caller-seeded ``Random`` —
    the full schedule is deterministic under a fixed seed."""

    max_retries: int = 3
    base_ms: float = 10.0
    multiplier: float = 2.0
    jitter_frac: float = 0.5

    def backoff_ms(self, attempt: int, rng: random.Random) -> float:
        raw = self.base_ms * self.multiplier ** attempt
        return raw + rng.random() * self.jitter_frac * raw

    def schedule_ms(self, rng: random.Random) -> List[float]:
        """The full backoff schedule a fresh ``rng`` would produce —
        what the deterministic-retry tests assert against."""
        return [
            self.backoff_ms(a, rng) for a in range(self.max_retries)
        ]


def retry_call(
    fn: Callable[[], object],
    policy: RetryPolicy,
    clock,
    rng: random.Random,
    classify: Callable[[BaseException], bool] = is_retryable,
    on_retry: Optional[Callable[[int, BaseException], None]] = None,
) -> Tuple[object, int]:
    """Run ``fn`` with up to ``policy.max_retries`` retries on transient
    failures.  Returns ``(result, retries_used)``; non-retryable
    exceptions (and exhaustion) propagate to the caller."""
    attempt = 0
    while True:
        try:
            return fn(), attempt
        except BaseException as exc:  # noqa: BLE001 - classified below
            if attempt >= policy.max_retries or not classify(exc):
                raise
            if on_retry is not None:
                on_retry(attempt, exc)
            clock.sleep(policy.backoff_ms(attempt, rng) / 1e3)
            attempt += 1


# ----------------------------------------------------------------------
# shard health
# ----------------------------------------------------------------------
class ShardHealth:
    """Per-shard failure + straggler tracking feeding backend demotion.

    * ``record_failure(shard)`` — hard failures (a ``ShardFailure`` from
      fault injection or a real launch error); at ``fail_threshold`` the
      shard joins ``dead``.
    * ``record_launch(shard, seconds)`` — wall-time observations run
      through one ``StragglerDetector`` per shard (the training-side
      EWMA, reused — see ``distributed.health``); a sustained straggle
      puts the shard in ``slow``, and with ``demote_slow=True`` also in
      ``dead`` (a shard answering 10x late is as useless to a deadline
      as one answering never).
    """

    def __init__(
        self,
        n_shards: int,
        fail_threshold: int = 1,
        demote_slow: bool = False,
        detector_factory: Callable[[], StragglerDetector] = (
            StragglerDetector
        ),
        metrics=None,
    ):
        self.n_shards = int(n_shards)
        self.fail_threshold = int(fail_threshold)
        self.demote_slow = bool(demote_slow)
        self._detectors = [detector_factory() for _ in range(n_shards)]
        self._failures = [0] * n_shards
        self.dead: set = set()
        self.slow: set = set()
        self.events: List[dict] = []
        self._step = 0
        # optional obs.MetricsRegistry: every health event doubles as a
        # counter (the ordered ``events`` list stays the source of truth
        # for sequence assertions)
        self.metrics = metrics

    def _event(self, kind: str, shard: int) -> None:
        self.events.append({"kind": kind, "shard": shard})
        if self.metrics is not None:
            self.metrics.counter(
                "serve.shard_events", kind=kind, shard=shard
            ).inc()

    def record_failure(self, shard: int) -> bool:
        """Returns True when this failure kills the shard."""
        s = int(shard)
        if not 0 <= s < self.n_shards:
            raise ValueError(
                f"shard {s} out of range for {self.n_shards} shards"
            )
        self._failures[s] += 1
        self._event("failure", s)
        if self._failures[s] >= self.fail_threshold and s not in self.dead:
            self.dead.add(s)
            self._event("dead", s)
            return True
        return False

    def record_launch(self, shard: int, seconds: float) -> bool:
        """Feed one launch wall-time; returns True on sustained straggle."""
        s = int(shard)
        self._step += 1
        if self._detectors[s].observe(self._step, float(seconds)):
            self.slow.add(s)
            self._event("slow", s)
            if self.demote_slow and s not in self.dead:
                self.dead.add(s)
                self._event("dead", s)
            return True
        return False

    @property
    def healthy(self) -> bool:
        return not self.dead

    def dead_shards(self) -> Tuple[int, ...]:
        return tuple(sorted(self.dead))


# ----------------------------------------------------------------------
# the demotion ladder
# ----------------------------------------------------------------------
class ResilientTrieEngine:
    """A ``TrieQueryEngine`` front that survives shard failure.

    Backend ladder, walked per call based on ``health.dead``:

    1. ``primary`` — whatever the caller built (usually sharded).
    2. replicated fallback — a fresh single-device engine over the SAME
       ``FrozenTrie`` (built lazily on first demotion); bit-identical
       answers, so responses stay ``degraded=False``.
    3. dead-shard-masked degraded plan — when replicated fallback is
       disallowed (``allow_replicated_fallback=False``, e.g. the trie
       does not fit one device), queries run over
       ``mask_dead_shards(primary.plan, dead)``: partial answers,
       flagged ``degraded=True``.

    A ``ShardFailure`` raised mid-call records the failure and RE-RUNS
    the same call on the demoted backend before returning — in-flight
    requests are never dropped on a shard death.
    """

    OPS = ("rule_search_batch", "top_k_rules_batch", "rules_with")

    def __init__(
        self,
        primary,
        health: Optional[ShardHealth] = None,
        allow_replicated_fallback: bool = True,
        obs=None,
    ):
        self.primary = primary
        self.health = health or ShardHealth(primary.n_shards)
        self.allow_replicated_fallback = bool(allow_replicated_fallback)
        self._replicated = None
        self._degraded = None
        self._degraded_for: Tuple = ()
        self.failovers = 0
        self._obs = None
        if obs is not None:
            self.obs = obs

    # -- observability ------------------------------------------------
    @property
    def obs(self):
        """The ``Observability`` bundle this engine reports into.  The
        scheduler assigns its own on construction (unless one was given
        explicitly); the setter fans it out to the health tracker and
        every backend engine so failover transitions, shard events, and
        engine-level spans all land in one registry/tracer."""
        return self._obs

    @obs.setter
    def obs(self, value) -> None:
        self._obs = value
        self.health.metrics = value.metrics if value is not None else None
        for eng in (self.primary, self._replicated, self._degraded):
            if eng is not None and hasattr(eng, "obs"):
                eng.obs = value

    # -- backend selection --------------------------------------------
    def _replicated_engine(self):
        if self._replicated is None:
            from repro.serve.trie_engine import TrieQueryEngine

            # a streaming primary falls back over the STREAM, not its
            # frozen base — the replicated engine keeps merging the
            # delta, so failover answers stay bit-identical
            trie = getattr(self.primary, "stream", None)
            self._replicated = TrieQueryEngine(
                trie if trie is not None else self.primary.frozen,
                mode="replicated",
            )
            self._replicated.obs = self._obs
        return self._replicated

    def _degraded_engine(self):
        dead = self.health.dead_shards()
        # epoch in the cache key: a refreeze swaps the frozen base, so
        # the masked plan must be rebuilt from the NEW plan — serving a
        # pre-fold masked plan would answer over a stale trie
        key = (dead, self.epoch)
        if self._degraded is None or self._degraded_for != key:
            from repro.distributed.trie_sharding import mask_dead_shards
            from repro.serve.trie_engine import TrieQueryEngine

            stream = getattr(self.primary, "stream", None)
            self._degraded = TrieQueryEngine(
                stream if stream is not None else self.primary.frozen,
                plan=mask_dead_shards(self.primary.plan, dead),
            )
            self._degraded.obs = self._obs
            self._degraded_for = key
        return self._degraded

    def _active(self):
        """→ ``(engine, degraded, backend_name)`` for the current health."""
        has_plan = getattr(self.primary, "plan", None) is not None
        if self.health.dead and has_plan:
            if self.allow_replicated_fallback:
                return self._replicated_engine(), False, "replicated"
            return self._degraded_engine(), True, "degraded"
        return self.primary, False, self.primary.backend

    @property
    def backend(self) -> str:
        return self._active()[2]

    @property
    def frozen(self):
        return self.primary.frozen

    @property
    def n_shards(self) -> int:
        return self.primary.n_shards

    @property
    def epoch(self) -> int:
        """Trie-version epoch of the underlying (streaming) engine; 0
        for a plain frozen engine."""
        return int(getattr(self.primary, "epoch", 0))

    @property
    def version(self) -> Tuple[int, int]:
        """``(failovers, epoch)`` — changes whenever cached results could
        go stale: a failover reroutes queries, an insert/refreeze changes
        the trie contents.  The scheduler folds this into its LRU cache
        key, so a version bump orphans every older entry."""
        return (self.failovers, self.epoch)

    # -- streaming passthroughs ---------------------------------------
    def insert(self, sequences, support, confidence, lift) -> int:
        """Absorb inserted/updated rules (streaming primary only)."""
        return self.primary.insert(sequences, support, confidence, lift)

    def maybe_refreeze(self):
        return self.primary.maybe_refreeze()

    # -- the resilient call -------------------------------------------
    def query(self, op: str, *args, **kwargs) -> Tuple[Dict, Dict]:
        """Run one batched op; returns ``(result, info)`` with
        ``info = {"degraded": bool, "backend": str, "failover": bool}``."""
        from repro.distributed.trie_sharding import ShardFailure

        if op not in self.OPS:
            raise ValueError(f"op {op!r} not in {self.OPS}")
        engine, degraded, backend = self._active()
        try:
            result = getattr(engine, op)(*args, **kwargs)
            return result, {
                "degraded": degraded, "backend": backend,
                "failover": False,
            }
        except ShardFailure as exc:
            obs = self._obs
            prev_backend = backend
            fspan = (obs.tracer.start("failover", shard=int(exc.shard))
                     if obs is not None else None)
            self.health.record_failure(exc.shard)
            self.failovers += 1
            engine, degraded, backend = self._active()
            if obs is not None:
                # the demotion-ladder transition counter the shard-kill
                # regression test asserts: sharded → replicated|degraded
                obs.metrics.counter("serve.failover", labels={
                    "from": prev_backend, "to": backend,
                }).inc()
                obs.tracer.annotate(
                    fspan, **{"from": prev_backend, "to": backend})
            result = getattr(engine, op)(*args, **kwargs)
            if obs is not None:
                obs.tracer.end(fspan)
            return result, {
                "degraded": degraded, "backend": backend,
                "failover": True,
            }
