"""Serving steps: prefill (populate cache, last-token logits) and decode
(one token per step against the KV/SSM cache).

Both are pure functions of (params, cache, tokens) so the launcher can jit
them with donated caches — the cache buffer is updated in place on device.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.model import decode_step


def make_prefill_step(cfg):
    def prefill(params, cache, batch):
        logits, _extras, new_cache = forward(
            cfg, params, batch, cache=cache, logits_mode="last"
        )
        return logits, new_cache

    return prefill


def make_decode_step(cfg):
    def decode(params, cache, tokens):
        return decode_step(cfg, params, cache, tokens)

    return decode


def greedy_generate(cfg, params, cache, prompt_tokens, n_steps: int):
    """Host loop: prefill the prompt then greedy-decode ``n_steps``."""
    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))
    logits, cache = prefill(params, cache, {"tokens": prompt_tokens})
    out = []
    tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
    for _ in range(n_steps):
        out.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(
            jnp.int32
        )
    return jnp.concatenate(out, axis=1), cache
