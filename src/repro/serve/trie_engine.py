"""Trie query serving: one front door over the replicated and sharded
engines.

``TrieQueryEngine`` owns a frozen trie's device residency and routes the
three batched query ops (``rule_search_batch`` / ``top_k_rules_batch`` /
``rules_with``) to one of two bit-identical backends:

* ``"replicated"`` — the whole trie on one device (a ``DeviceTrie`` plus
  the memoized gather dicts), queries run as single-device one-launch
  kernels.  Right for small tries and single-device hosts: no collective
  latency, no partitioning work.
* ``"sharded"`` — the trie partitioned into contiguous DFS subtree
  ranges across the ``("data",)`` mesh
  (``distributed.trie_sharding.shard_device_trie``), queries run under
  ``shard_map`` with k-best/found-winner merges.  Right when the trie
  outgrows one device's memory or its tile sweep dominates latency —
  each device scans ``~N/P`` nodes per ranked query.

``mode="auto"`` picks sharded exactly when there is more than one device
to shard over AND the trie clears ``shard_threshold_nodes`` (default
64Ki nodes — below that the per-launch tile sweep is a handful of tiles
and the all-gather merge would dominate).  Both backends answer through
the SAME ``kernels.ops`` entry points and are bit-identical (tie order
included), so routing is purely a performance decision.
"""
from __future__ import annotations

from typing import Dict, Sequence

import jax

from repro.core.array_trie import FrozenTrie
from repro.kernels import ops as trie_ops

DEFAULT_SHARD_THRESHOLD = 1 << 16   # nodes


class TrieQueryEngine:
    """Serving front door for one frozen Trie of Rules."""

    def __init__(
        self,
        frozen: FrozenTrie,
        mesh=None,
        mode: str = "auto",
        shard_threshold_nodes: int = DEFAULT_SHARD_THRESHOLD,
        plan=None,
    ):
        if mode not in ("auto", "replicated", "sharded"):
            raise ValueError(
                f"mode {mode!r} not in ('auto', 'replicated', 'sharded')"
            )
        self.frozen = frozen
        self.plan = None
        self._dt = None
        self._edges = None
        self._dfs_arrays = None
        self._item_arrays = None
        if plan is not None:
            # pre-built (possibly dead-shard-masked) ShardPlan injection:
            # the resilience layer's degraded engines hand their masked
            # plan straight in, skipping the (re)partitioning work
            self.plan = plan
            self.mesh = plan.mesh
            return
        if mode != "replicated" and mesh is None and jax.device_count() > 1:
            from repro.launch.mesh import make_trie_mesh

            mesh = make_trie_mesh()
        n_dev = int(mesh.shape["data"]) if mesh is not None else 1
        sharded = mode == "sharded" or (
            mode == "auto"
            and n_dev > 1
            and frozen.n_nodes >= shard_threshold_nodes
        )
        if sharded:
            if mesh is None:
                from repro.launch.mesh import make_trie_mesh

                mesh = make_trie_mesh()
            from repro.distributed.trie_sharding import shard_device_trie

            self.plan = shard_device_trie(frozen, mesh)
        self.mesh = mesh

    # ------------------------------------------------------------------
    @property
    def backend(self) -> str:
        return "sharded" if self.plan is not None else "replicated"

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards if self.plan is not None else 1

    def _device_trie(self):
        if self._dt is None:
            self._dt = self.frozen.device_arrays()
        return self._dt

    # ------------------------------------------------------------------
    # the three batched ops (thin routing over kernels.ops)
    # ------------------------------------------------------------------
    def rule_search_batch(self, queries, ant_len=None) -> Dict:
        if self.plan is not None:
            return trie_ops.rule_search_batch(self.plan, queries, ant_len)
        if self._edges is None:
            self._edges = trie_ops.edge_metric_arrays(self._device_trie())
        # the FrozenTrie keeps ragged-pair canonicalization host-side
        return trie_ops.rule_search_batch(
            self.frozen, queries, ant_len, edges=self._edges
        )

    def top_k_rules_batch(
        self, prefixes, k: int, metric: str = "confidence",
        min_depth: int = 1,
    ) -> Dict:
        if self.plan is not None:
            return trie_ops.top_k_rules_batch(
                self.plan, prefixes, k, metric=metric, min_depth=min_depth
            )
        if self._dfs_arrays is None:
            self._dfs_arrays = trie_ops.dfs_rank_arrays(self._device_trie())
            self._dfs_arrays["_device_trie"] = self._device_trie()
        return trie_ops.top_k_rules_batch(
            self.frozen, prefixes, k, metric=metric, min_depth=min_depth,
            arrays=self._dfs_arrays,
        )

    def rules_with(
        self, items: Sequence[int], role: str = "any", k: int = 10,
        metric: str = "confidence", min_depth: int = 1,
    ) -> Dict:
        if self.plan is not None:
            return trie_ops.rules_with(
                self.plan, items, role=role, k=k, metric=metric,
                min_depth=min_depth,
            )
        if self._item_arrays is None:
            self._item_arrays = trie_ops.item_rank_arrays(
                self._device_trie()
            )
        return trie_ops.rules_with(
            self.frozen, items, role=role, k=k, metric=metric,
            min_depth=min_depth, arrays=self._item_arrays,
        )


def make_trie_engine(
    frozen: FrozenTrie,
    mesh=None,
    mode: str = "auto",
    shard_threshold_nodes: int = DEFAULT_SHARD_THRESHOLD,
) -> TrieQueryEngine:
    """Factory alias (mirrors the ``make_*_step`` serving constructors)."""
    return TrieQueryEngine(
        frozen, mesh=mesh, mode=mode,
        shard_threshold_nodes=shard_threshold_nodes,
    )
