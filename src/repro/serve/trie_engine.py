"""Trie query serving: one front door over the replicated and sharded
engines.

``TrieQueryEngine`` owns a frozen trie's device residency and routes the
three batched query ops (``rule_search_batch`` / ``top_k_rules_batch`` /
``rules_with``) to one of two bit-identical backends:

* ``"replicated"`` — the whole trie on one device (a ``DeviceTrie`` plus
  the memoized gather dicts), queries run as single-device one-launch
  kernels.  Right for small tries and single-device hosts: no collective
  latency, no partitioning work.
* ``"sharded"`` — the trie partitioned into contiguous DFS subtree
  ranges across the ``("data",)`` mesh
  (``distributed.trie_sharding.shard_device_trie``), queries run under
  ``shard_map`` with k-best/found-winner merges.  Right when the trie
  outgrows one device's memory or its tile sweep dominates latency —
  each device scans ``~N/P`` nodes per ranked query.

``mode="auto"`` picks sharded exactly when there is more than one device
to shard over AND the trie clears ``shard_threshold_nodes`` (default
64Ki nodes — below that the per-launch tile sweep is a handful of tiles
and the all-gather merge would dominate).  Both backends answer through
the SAME ``kernels.ops`` entry points and are bit-identical (tie order
included), so routing is purely a performance decision.

The engine also fronts a ``core.delta_trie.StreamingTrie`` — a frozen
base plus a mutable delta overlay.  Queries then run through
``kernels.streaming`` (frozen+delta k-best merges, bit-identical to a
from-scratch rebuild), ``insert`` absorbs new/updated rules, and
``maybe_refreeze`` runs the staggered fold.  ``epoch`` exposes the
stream's trie-version counter (bumps on every insert and refreeze) so
callers — the scheduler's result cache above all — can tell whether a
cached answer predates the current trie contents.  ``frozen`` and
``plan`` are properties for this reason: a refreeze swaps the frozen
base, and the engine must never serve a query half over the old trie
and half over the new one.
"""
from __future__ import annotations

from contextlib import nullcontext
from typing import Dict, Optional, Sequence

import jax

from repro.core.array_trie import FrozenTrie
from repro.core.delta_trie import StreamingTrie
from repro.kernels import ops as trie_ops

DEFAULT_SHARD_THRESHOLD = 1 << 16   # nodes


class TrieQueryEngine:
    """Serving front door for one frozen Trie of Rules."""

    def __init__(
        self,
        frozen,                 # FrozenTrie | StreamingTrie
        mesh=None,
        mode: str = "auto",
        shard_threshold_nodes: int = DEFAULT_SHARD_THRESHOLD,
        plan=None,
    ):
        if mode not in ("auto", "replicated", "sharded"):
            raise ValueError(
                f"mode {mode!r} not in ('auto', 'replicated', 'sharded')"
            )
        # set by the resilience layer / scheduler; when present, each op
        # runs under an ``engine.<op>`` span on the shared tracer
        self.obs = None
        self.stream = None
        if isinstance(frozen, StreamingTrie):
            self.stream = frozen
            frozen = None
        self._frozen = frozen
        self._plan = None
        self._stream_sharded = False
        self._dt = None
        self._edges = None
        self._dfs_arrays = None
        self._item_arrays = None
        if plan is not None:
            # pre-built (possibly dead-shard-masked) ShardPlan injection:
            # the resilience layer's degraded engines hand their masked
            # plan straight in, skipping the (re)partitioning work.  With
            # a stream the injected plan overrides the stream's own —
            # delta merges keep running over the masked residency.
            self._plan = plan
            self.mesh = plan.mesh
            return
        if mode != "replicated" and mesh is None and jax.device_count() > 1:
            from repro.launch.mesh import make_trie_mesh

            mesh = make_trie_mesh()
        n_dev = int(mesh.shape["data"]) if mesh is not None else 1
        sharded = mode == "sharded" or (
            mode == "auto"
            and n_dev > 1
            and self.frozen.n_nodes >= shard_threshold_nodes
        )
        if self.stream is not None:
            if sharded:
                if self.stream.mesh is not None:
                    mesh = self.stream.mesh
                else:
                    if mesh is None:
                        from repro.launch.mesh import make_trie_mesh

                        mesh = make_trie_mesh()
                    # the engine owns residency: hand the stream its mesh
                    # before any plan is cached
                    self.stream.mesh = mesh
                self._stream_sharded = True
            self.mesh = mesh
            return
        if sharded:
            if mesh is None:
                from repro.launch.mesh import make_trie_mesh

                mesh = make_trie_mesh()
            from repro.distributed.trie_sharding import shard_device_trie

            self._plan = shard_device_trie(frozen, mesh)
        self.mesh = mesh

    # ------------------------------------------------------------------
    @property
    def frozen(self) -> FrozenTrie:
        """The current frozen base — re-read per call because a refreeze
        swaps it (the stream's epoch says which version answered)."""
        if self.stream is not None:
            return self.stream.frozen
        return self._frozen

    @property
    def plan(self):
        if self.stream is not None and self._plan is None:
            return (
                self.stream.shard_plan() if self._stream_sharded else None
            )
        return self._plan

    @property
    def epoch(self) -> int:
        """Trie-version counter (0 for a plain frozen engine): bumps on
        every insert and refreeze, so any result cache keyed on it can
        never return a pre-insert row for a post-insert trie."""
        return self.stream.epoch if self.stream is not None else 0

    @property
    def backend(self) -> str:
        return "sharded" if self.plan is not None else "replicated"

    @property
    def n_shards(self) -> int:
        return self.plan.n_shards if self.plan is not None else 1

    def _device_trie(self):
        if self._dt is None:
            self._dt = self.frozen.device_arrays()
        return self._dt

    def _span(self, name: str, **attrs):
        """Engine-level trace span (no-op context when obs is unset).
        Parents under the tracer's current scoped span — the scheduler's
        ``launch`` span when called from the serve loop."""
        if self.obs is None:
            return nullcontext()
        return self.obs.tracer.span(
            name, backend=self.backend, shards=self.n_shards, **attrs
        )

    def _stream_base(self):
        """Residency override handed to ``kernels.streaming``: an
        injected (dead-shard-masked) plan wins; a replicated engine over
        a mesh-bearing stream pins the frozen base instead of the
        stream's plan; ``None`` lets the stream route itself (queries
        then go through the validating ``kernels.ops`` dispatch)."""
        if self._plan is not None:
            return self._plan
        if self.stream.mesh is not None and not self._stream_sharded:
            return self.stream.frozen
        return None

    # ------------------------------------------------------------------
    # streaming mutation surface
    # ------------------------------------------------------------------
    def insert(self, sequences, support, confidence, lift) -> int:
        """Absorb inserted/updated rules into the delta overlay (bumps
        ``epoch``).  Requires a ``StreamingTrie``-backed engine."""
        if self.stream is None:
            raise TypeError(
                "insert requires a StreamingTrie-backed engine; build "
                "one with TrieQueryEngine(StreamingTrie(frozen), ...)"
            )
        return self.stream.insert(sequences, support, confidence, lift)

    def maybe_refreeze(self) -> Optional[int]:
        """Run one staggered fold step if the delta is over threshold;
        returns the folded depth-1 item (None when nothing folded).  The
        serve loop calls this between launches, so the frozen-base swap
        is atomic w.r.t. in-flight queries."""
        if self.stream is None:
            return None
        return self.stream.maybe_refreeze()

    # ------------------------------------------------------------------
    # the three batched ops (thin routing over kernels.ops)
    # ------------------------------------------------------------------
    def rule_search_batch(self, queries, ant_len=None) -> Dict:
        with self._span("engine.rule_search_batch", n=len(queries)):
            return self._rule_search_batch(queries, ant_len)

    def _rule_search_batch(self, queries, ant_len=None) -> Dict:
        if self.stream is not None:
            base = self._stream_base()
            if base is None:
                return trie_ops.rule_search_batch(
                    self.stream, queries, ant_len
                )
            from repro.kernels.streaming import streaming_rule_search_batch

            return streaming_rule_search_batch(
                self.stream, queries, ant_len, base=base
            )
        if self.plan is not None:
            return trie_ops.rule_search_batch(self.plan, queries, ant_len)
        if self._edges is None:
            self._edges = trie_ops.edge_metric_arrays(self._device_trie())
        # the FrozenTrie keeps ragged-pair canonicalization host-side
        return trie_ops.rule_search_batch(
            self.frozen, queries, ant_len, edges=self._edges
        )

    def top_k_rules_batch(
        self, prefixes, k: int, metric: str = "confidence",
        min_depth: int = 1,
    ) -> Dict:
        with self._span("engine.top_k_rules_batch", n=len(prefixes), k=k):
            return self._top_k_rules_batch(
                prefixes, k, metric=metric, min_depth=min_depth
            )

    def _top_k_rules_batch(
        self, prefixes, k: int, metric: str = "confidence",
        min_depth: int = 1,
    ) -> Dict:
        if self.stream is not None:
            base = self._stream_base()
            if base is None:
                return trie_ops.top_k_rules_batch(
                    self.stream, prefixes, k, metric=metric,
                    min_depth=min_depth,
                )
            from repro.kernels.streaming import streaming_top_k_rules_batch

            return streaming_top_k_rules_batch(
                self.stream, prefixes, k, metric=metric,
                min_depth=min_depth, base=base,
            )
        if self.plan is not None:
            return trie_ops.top_k_rules_batch(
                self.plan, prefixes, k, metric=metric, min_depth=min_depth
            )
        if self._dfs_arrays is None:
            self._dfs_arrays = trie_ops.dfs_rank_arrays(self._device_trie())
            self._dfs_arrays["_device_trie"] = self._device_trie()
        return trie_ops.top_k_rules_batch(
            self.frozen, prefixes, k, metric=metric, min_depth=min_depth,
            arrays=self._dfs_arrays,
        )

    def rules_with(
        self, items: Sequence[int], role: str = "any", k: int = 10,
        metric: str = "confidence", min_depth: int = 1,
    ) -> Dict:
        with self._span("engine.rules_with", n=len(items), k=k):
            return self._rules_with(
                items, role=role, k=k, metric=metric, min_depth=min_depth
            )

    def _rules_with(
        self, items: Sequence[int], role: str = "any", k: int = 10,
        metric: str = "confidence", min_depth: int = 1,
    ) -> Dict:
        if self.stream is not None:
            base = self._stream_base()
            if base is None:
                return trie_ops.rules_with(
                    self.stream, items, role=role, k=k, metric=metric,
                    min_depth=min_depth,
                )
            from repro.kernels.streaming import streaming_rules_with

            return streaming_rules_with(
                self.stream, items, role=role, k=k, metric=metric,
                min_depth=min_depth, base=base,
            )
        if self.plan is not None:
            return trie_ops.rules_with(
                self.plan, items, role=role, k=k, metric=metric,
                min_depth=min_depth,
            )
        if self._item_arrays is None:
            self._item_arrays = trie_ops.item_rank_arrays(
                self._device_trie()
            )
        return trie_ops.rules_with(
            self.frozen, items, role=role, k=k, metric=metric,
            min_depth=min_depth, arrays=self._item_arrays,
        )


def make_trie_engine(
    frozen,
    mesh=None,
    mode: str = "auto",
    shard_threshold_nodes: int = DEFAULT_SHARD_THRESHOLD,
) -> TrieQueryEngine:
    """Factory alias (mirrors the ``make_*_step`` serving constructors).
    ``frozen`` may be a ``FrozenTrie`` or a ``StreamingTrie``."""
    return TrieQueryEngine(
        frozen, mesh=mesh, mode=mode,
        shard_threshold_nodes=shard_threshold_nodes,
    )
