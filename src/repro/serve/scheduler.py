"""Resilient continuous-batching serve loop over ``TrieQueryEngine``.

The paper's claim is that the Trie of Rules makes *serving a ruleset*
fast; this module turns the one-batch-per-call engine into a system that
survives production traffic: a stream of ragged, skewed, mixed-op
requests that duplicate heavily, carry deadlines, and outlive failed
shards.  The JetStream-style loop:

    submit() ──► bounded admission queue ──► step(): ──► Response
                 (QueueFull beyond            1 expire deadlines
                  max_pending; shed           2 serve LRU-cache hits
                  policy pluggable)           3 shape one bucket batch
                                                (same op+kwargs, ≤
                                                max_batch, pow2-padded
                                                by the kernels)
                                              4 dedup identical rows
                                              5 launch w/ retry+backoff
                                                (ShardFailure → the
                                                resilience ladder
                                                demotes mid-call)
                                              6 scatter rows, fill cache

Every request is ONE query row (a rule pair, a ranked prefix, or an
item), so canonical-key hashing gives whole-query dedup for free: the
key that addresses the LRU result cache is the same key that collapses
duplicates inside a batch, lifting the per-item dedup ``rules_with``
already does to whole queries of every op.  Cache addresses are
versioned with the engine's ``(failovers, epoch)`` — a streaming insert
or refreeze (or a shard failover) orphans every older entry, so a
post-insert query can never be answered by a pre-insert row.

A fourth op, ``insert``, feeds a ``StreamingTrie``-backed engine: all
pending inserts apply host-side at the top of ``step()`` in arrival
order (writes never ride a query batch, are never deduped, never
cached), followed by at most one staggered refreeze fold — the
single-threaded step loop makes the frozen-base swap atomic w.r.t.
in-flight queries.

Deadlines are enforced at three points: queued requests past their
``deadline_ms`` expire to ``Timeout`` (never a hang), the batch shaper
refuses to pack a request whose predicted launch (per-bucket EWMA of
measured service time) would bust its budget — it times out immediately
instead of poisoning a batch it cannot survive — and post-launch expiry
still returns ``Timeout`` (the computed row only feeds the cache).

Time flows through the ``resilience`` clock seam: tests and the bench
replay drive a ``VirtualClock`` (deterministic backoff/deadline
behavior, injected fault latency), while a separate real ``timer``
measures kernel service time and charges it to the virtual timeline —
honest latency distributions under a reproducible arrival process.
"""
from __future__ import annotations

import dataclasses
import math
import random
from collections import OrderedDict, deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.array_trie import canonical_prefix_rows
from repro.kernels.ops import (
    InvalidQueryError,
    validate_items,
    validate_prefixes,
    validate_rule_pairs,
)
from repro.kernels.tuning import launch_pad
from repro.obs import Observability
from repro.serve.resilience import (
    MonotonicClock,
    ResilientTrieEngine,
    RetryPolicy,
    retry_call,
)

OPS = ("rule_search", "top_k", "rules_with", "insert")

# the stable stats-snapshot schema: every key pre-seeded at construction
# (``inserted``/``refreezes`` used to appear lazily on first insert)
STAT_KEYS = (
    "submitted", "ok", "timeout", "shed", "failed", "invalid",
    "cache_hits", "dedup_collapsed", "retries", "launches",
    "inserted", "refreezes",
)

# Response.status values
OK = "ok"
TIMEOUT = "timeout"
SHED = "shed"
FAILED = "failed"
INVALID = "invalid"


class QueueFull(Exception):
    """Admission rejected: the pending queue is at ``max_pending`` and
    the shed policy chose to reject the newcomer."""

    def __init__(self, request=None):
        self.request = request
        super().__init__("admission queue full")


@dataclasses.dataclass
class Request:
    """One query row travelling through the loop."""

    id: int
    op: str                      # "rule_search" | "top_k" | "rules_with"
    payload: object              # (ant, con) | prefix items | item id
    kwargs: Dict                 # op kwargs (k / metric / role / ...)
    tenant: str
    deadline_ms: float           # budget from submit; inf = none
    submit_s: float              # clock time at admission
    key: Tuple = ()              # canonical whole-query key (dedup+cache)
    bucket: Tuple = ()           # batchable group: (op, kwargs signature)
    canon: object = None         # canonical payload for batch assembly
    span: object = None          # root trace span (None when tracing off)
    qspan: object = None         # "queue" child span, open while queued
    sspan: object = None         # "serve" child span, open while batched

    def expires_s(self) -> float:
        if math.isinf(self.deadline_ms):
            return math.inf
        return self.submit_s + self.deadline_ms / 1e3


@dataclasses.dataclass
class Response:
    id: int
    op: str
    tenant: str
    status: str                  # OK / TIMEOUT / SHED / FAILED / INVALID
    result: Optional[Dict] = None   # per-row numpy slice of the op output
    degraded: bool = False       # answered over a dead-shard-masked plan
    backend: str = ""            # "sharded"/"replicated"/"degraded"/"cache"
    cache_hit: bool = False
    retries: int = 0
    latency_ms: float = 0.0
    error: str = ""

    @property
    def ok(self) -> bool:
        return self.status == OK


class LaunchPredictor:
    """EWMA of measured service seconds per (bucket, pow2 batch size) —
    the batch shaper's deadline oracle.

    An unseen shape seeds from the NEAREST observed pow2 batch size of
    the same bucket (nearest in log2 — a 64-row launch is a far better
    prior for 128 rows than ``default_ms``; service time grows roughly
    linearly in padded rows, so the adjacent bucket is within ~2x while
    the cold default is unboundedly wrong).  Only a bucket with no
    observations at ANY batch size predicts ``default_ms`` (0 by
    default: never preemptively time out before the first observation).
    """

    def __init__(self, alpha: float = 0.3, default_ms: float = 0.0):
        self.alpha = float(alpha)
        self.default_ms = float(default_ms)
        self._ewma_ms: Dict[Tuple, float] = {}

    @staticmethod
    def _shape(bucket: Tuple, batch: int) -> Tuple:
        return (*bucket, launch_pad(batch))

    def predict_ms(self, bucket: Tuple, batch: int) -> float:
        key = self._shape(bucket, batch)
        got = self._ewma_ms.get(key)
        if got is not None:
            return got
        # nearest observed pow2 size for this bucket; ties prefer the
        # smaller size (under-prediction only delays a timeout until the
        # first real observation corrects it)
        pad = key[-1]
        sizes = [
            k[-1] for k in self._ewma_ms if k[:-1] == key[:-1]
        ]
        if not sizes:
            return self.default_ms
        near = min(
            sizes,
            key=lambda s: (abs(math.log2(s) - math.log2(pad)), s),
        )
        return self._ewma_ms[(*key[:-1], near)]

    def observe(self, bucket: Tuple, batch: int, seconds: float) -> None:
        key = self._shape(bucket, batch)
        ms = float(seconds) * 1e3
        prev = self._ewma_ms.get(key)
        self._ewma_ms[key] = ms if prev is None else (
            (1 - self.alpha) * prev + self.alpha * ms
        )


class TrieScheduler:
    """Continuous-batching scheduler over a (resilient) trie engine.

    ``engine`` may be a plain ``TrieQueryEngine`` (wrapped into a
    ``ResilientTrieEngine`` automatically), an already-wrapped resilient
    engine, or a fault-injected ``FaultyEngine`` wrapped by one.
    """

    def __init__(
        self,
        engine,
        max_pending: int = 256,
        max_batch: int = 64,
        cache_size: int = 1024,
        retry_policy: Optional[RetryPolicy] = None,
        shed_policy: Union[str, Callable] = "reject_new",
        clock=None,
        timer: Optional[Callable[[], float]] = None,
        seed: int = 0,
        strict_admission: bool = True,
        predictor: Optional[LaunchPredictor] = None,
        obs: Optional[Observability] = None,
    ):
        if not isinstance(engine, ResilientTrieEngine):
            engine = ResilientTrieEngine(engine)
        self.engine = engine
        # fixed query-matrix width: canonical rows are root paths, so the
        # trie's max depth bounds them; padding every launch to this pow2
        # width (and batches to pow2 rows) keeps the set of compiled
        # kernel shapes bounded under arbitrary traffic — no
        # recompile-per-batch-size storms.
        depth = np.asarray(getattr(self.frozen, "node_depth", [1]))
        max_w = int(depth.max()) if depth.size else 1
        self._qwidth = 1 << max(max_w - 1, 0).bit_length()
        self.max_pending = int(max_pending)
        self.max_batch = int(max_batch)
        self.retry_policy = retry_policy or RetryPolicy()
        if isinstance(shed_policy, str) and shed_policy not in (
            "reject_new", "drop_oldest"
        ):
            raise ValueError(
                f"shed_policy {shed_policy!r} not in "
                "('reject_new', 'drop_oldest') and not callable"
            )
        self.shed_policy = shed_policy
        self.strict_admission = bool(strict_admission)
        self.clock = clock or MonotonicClock()
        self._timer = timer
        self._rng = random.Random(seed)
        self.predictor = predictor or LaunchPredictor()
        self._pending: deque = deque()
        self._cache: OrderedDict = OrderedDict()
        self.cache_size = int(cache_size)
        self.responses: Dict[int, Response] = {}
        self._next_id = 0
        # observability: the metrics registry replaces the old ad-hoc
        # ``stats`` dict (read it back through the ``stats`` property);
        # instruments for the legacy keys are held directly so hot-path
        # cost stays one attribute lookup + an int add.  Tracing is off
        # unless the caller's Observability enables it.
        self.obs = obs if obs is not None else Observability()
        self.obs.bind_clock(self.clock)
        m = self.obs.metrics
        self._c = {k: m.counter("serve." + k) for k in STAT_KEYS}
        self._g_pending = m.gauge("serve.pending")
        self._g_cache = m.gauge("serve.cache_len")
        if getattr(self.engine, "obs", None) is None:
            self.engine.obs = self.obs
        # measured kernel wall time (when the profiler is scoped on)
        # becomes a queryable predictor bucket — see _observe_kernel
        if self.obs.profiler is not None:
            self.obs.profiler.add_observer(self._observe_kernel)

    @property
    def stats(self) -> Dict[str, int]:
        """Read-compatible snapshot of the legacy counters (now backed by
        ``obs.metrics``).  Schema is stable: every key is pre-seeded at
        construction, including ``inserted``/``refreezes``."""
        return {k: c.value for k, c in self._c.items()}

    def _observe_kernel(self, rec) -> None:
        """Kernel-ring observer: measured launch wall time lands in a
        ``("kernel", op)`` predictor bucket — disjoint from the
        service-time buckets the deadline shaper reads, so profiling
        never skews admission decisions."""
        self.predictor.observe(("kernel", rec.op), rec.rows, rec.seconds)

    @property
    def frozen(self):
        """The engine's CURRENT frozen base — a property because a
        streaming refreeze swaps it mid-stream (item tables, which all
        canonicalization reads, are fixed for the vocab either way)."""
        return self.engine.frozen

    # ------------------------------------------------------------------
    # admission
    # ------------------------------------------------------------------
    def _canonicalize(self, op, payload, kwargs):
        """→ ``(key, bucket, canon)``; raises ``InvalidQueryError`` on a
        malformed payload (strict mode also rejects out-of-vocab ids —
        poison never reaches a batch)."""
        strict = self.strict_admission
        rank = getattr(self.frozen, "item_rank", None)
        if op == "rule_search":
            ant, con = payload
            validate_rule_pairs(
                [(ant, con)], "rule_search_batch", item_rank=rank,
                strict=strict,
            )
            rows, als = self.frozen.canonicalize_queries([ant], [con])
            row = tuple(int(x) for x in np.asarray(rows)[0])
            al = int(np.asarray(als)[0])
            return ("rule_search", row, al), ("rule_search",), (row, al)
        if op == "top_k":
            validate_prefixes(
                [payload], "top_k_rules_batch", item_rank=rank,
                strict=strict,
            )
            crow = tuple(
                int(x) for x in canonical_prefix_rows([payload], rank)[0]
            )
            sig = (
                int(kwargs.get("k", 10)),
                str(kwargs.get("metric", "confidence")),
                int(kwargs.get("min_depth", 1)),
            )
            return ("top_k", crow, sig), ("top_k", sig), crow
        if op == "rules_with":
            it = validate_items(
                [payload], "rules_with",
                n_items=int(np.asarray(self.frozen.item_offsets).shape[0])
                - 1,
                strict=strict,
            )[0]
            sig = (
                str(kwargs.get("role", "any")),
                int(kwargs.get("k", 10)),
                str(kwargs.get("metric", "confidence")),
                int(kwargs.get("min_depth", 1)),
            )
            return ("rules_with", it, sig), ("rules_with", sig), it
        if op == "insert":
            seq, sup, conf, lift = payload
            if not len(seq):
                raise InvalidQueryError(
                    "insert: rule path must be non-empty"
                )
            validate_prefixes(
                [seq], "insert", item_rank=rank, strict=strict,
            )
            canon = (
                tuple(int(x) for x in seq),
                float(sup), float(conf), float(lift),
            )
            # keyed by admission id: inserts are WRITES — two identical
            # inserts must both apply (never deduped, never cached)
            return ("insert", self._next_id), ("insert",), canon
        raise InvalidQueryError(f"op {op!r} not in {OPS}")

    def submit(
        self,
        op: str,
        payload,
        kwargs: Optional[Dict] = None,
        deadline_ms: float = math.inf,
        tenant: str = "default",
    ) -> Request:
        """Admit one request; raises ``QueueFull`` when the bounded queue
        rejects it and ``InvalidQueryError`` on malformed payloads."""
        kwargs = dict(kwargs or {})
        tr = self.obs.tracer
        m = self.obs.metrics
        root = tr.start("request", parent=False, op=op, tenant=tenant,
                        req=self._next_id)
        admit = tr.start("admit", parent=root, op=op)
        try:
            key, bucket, canon = self._canonicalize(op, payload, kwargs)
        except InvalidQueryError:
            self._c["invalid"].inc()
            tr.end(admit, error="invalid")
            tr.end(root, status=INVALID)
            raise
        if len(self._pending) >= self.max_pending:
            victim = self._pick_victim()
            if victim is None:
                self._c["shed"].inc()
                m.counter("serve.shed_admission", tenant=tenant,
                          reason="reject_new").inc()
                tr.end(admit, error="shed")
                tr.end(root, status=SHED)
                raise QueueFull()
            self._pending.remove(victim)
            m.counter("serve.shed_admission", tenant=victim.tenant,
                      reason="drop_oldest").inc()
            self._finish(victim, Response(
                id=victim.id, op=victim.op, tenant=victim.tenant,
                status=SHED, error="shed by drop_oldest policy",
            ))
        req = Request(
            id=self._next_id, op=op, payload=payload, kwargs=kwargs,
            tenant=tenant, deadline_ms=float(deadline_ms),
            submit_s=self.clock.now(), key=key, bucket=bucket,
            canon=canon, span=root,
        )
        self._next_id += 1
        self._c["submitted"].inc()
        m.counter("serve.admitted", tenant=tenant, op=op).inc()
        tr.end(admit)
        req.qspan = tr.start("queue", parent=root)
        self._pending.append(req)
        return req

    def _pick_victim(self) -> Optional[Request]:
        if callable(self.shed_policy):
            return self.shed_policy(self._pending)
        if self.shed_policy == "drop_oldest" and self._pending:
            return self._pending[0]
        return None            # reject_new

    @property
    def pending(self) -> int:
        return len(self._pending)

    # ------------------------------------------------------------------
    # the serve step
    # ------------------------------------------------------------------
    def step(self) -> List[Response]:
        """Expire deadlines, serve cache hits, launch ONE shaped batch.
        Returns the responses completed by this step (possibly empty)."""
        done: List[Response] = []
        tr = self.obs.tracer
        sroot = tr.start("step", parent=False)
        try:
            self._expire(done)
            self._drain_inserts(done, parent=sroot)
            if not self._pending:
                return done

            # shape one batch: the head request's bucket, arrival order
            with tr.span("batch_form", parent=sroot) as bspan:
                bucket = self._pending[0].bucket
                batch: List[Request] = []
                keep: deque = deque()
                while self._pending:
                    r = self._pending.popleft()
                    if r.bucket == bucket and len(batch) < self.max_batch:
                        batch.append(r)
                    else:
                        keep.append(r)
                self._pending = keep
                tr.annotate(bspan, op=bucket[0], batch=len(batch))
                if tr.enabled:
                    for r in batch:
                        tr.end(r.qspan)
                        r.sspan = tr.start("serve", parent=r.span,
                                           op=r.op)

            with tr.span("dedup_cache", parent=sroot, op=bucket[0]):
                # cache hits never touch the kernels
                misses: List[Request] = []
                for r in batch:
                    hit = self._cache_get(r.key)
                    if hit is not None:
                        self._c["cache_hits"].inc()
                        done.append(self._finish(r, self._respond_ok(
                            r, hit, backend="cache", cache_hit=True,
                        )))
                    else:
                        misses.append(r)
                if not misses:
                    return done

                # whole-query dedup inside the batch
                unique: "OrderedDict[Tuple, List[Request]]" = OrderedDict()
                for r in misses:
                    unique.setdefault(r.key, []).append(r)
                self._c["dedup_collapsed"].inc(len(misses) - len(unique))

                # the deadline shaper: predicted service for THIS bucket
                # shape — a request that cannot survive the launch times
                # out now rather than riding (and slowing) a batch it
                # will miss anyway
                predicted_ms = self.predictor.predict_ms(
                    bucket, len(unique))
                now = self.clock.now()
                live: "OrderedDict[Tuple, List[Request]]" = OrderedDict()
                for key, reqs in unique.items():
                    still = []
                    for r in reqs:
                        if now + predicted_ms / 1e3 > r.expires_s():
                            done.append(self._finish(r, Response(
                                id=r.id, op=r.op, tenant=r.tenant,
                                status=TIMEOUT,
                                error=(
                                    f"predicted launch {predicted_ms:.1f}"
                                    f"ms busts deadline "
                                    f"{r.deadline_ms:.1f}ms"
                                ),
                                latency_ms=(now - r.submit_s) * 1e3,
                            )))
                        else:
                            still.append(r)
                    if still:
                        live[key] = still
                if not live:
                    return done

            done.extend(self._launch(bucket, live, parent=sroot))
            return done
        finally:
            self._g_pending.set(len(self._pending))
            self._g_cache.set(len(self._cache))
            tr.end(sroot, completed=len(done))

    def _drain_inserts(self, done: List[Response], parent=None) -> None:
        """Apply every pending insert, in arrival order, before any
        query batch is shaped.  Writes never ride a query batch: each
        one lands host-side immediately (bumping the engine epoch, which
        orphans every cached pre-insert row), and at most ONE staggered
        refreeze fold runs per step — the single-threaded step loop is
        what makes the frozen-base swap atomic w.r.t. in-flight queries.
        """
        if not any(r.op == "insert" for r in self._pending):
            return
        tr = self.obs.tracer
        with tr.span("insert_drain", parent=parent) as dspan:
            keep: deque = deque()
            inserts: List[Request] = []
            while self._pending:
                r = self._pending.popleft()
                (inserts if r.op == "insert" else keep).append(r)
            self._pending = keep
            tr.annotate(dspan, n=len(inserts))
            if tr.enabled:
                for r in inserts:
                    tr.end(r.qspan)
                    r.sspan = tr.start("serve", parent=r.span, op="insert")
            for r in inserts:
                seq, sup, conf, lift = r.canon
                try:
                    self.engine.insert([seq], [sup], [conf], [lift])
                except (TypeError, ValueError) as exc:
                    # non-streaming engine (TypeError) or a rejected rule
                    # (out-of-vocab / prefix-closure): isolated per request
                    done.append(self._finish(r, Response(
                        id=r.id, op=r.op, tenant=r.tenant, status=INVALID,
                        error=repr(exc),
                        latency_ms=(self.clock.now() - r.submit_s) * 1e3,
                    )))
                    continue
                self._c["inserted"].inc()
                done.append(self._finish(r, self._respond_ok(
                    r, {"epoch": self.engine.epoch}, backend="insert",
                )))
            with tr.span("refreeze", parent=dspan) as fspan:
                folded = self.engine.maybe_refreeze()
                tr.annotate(
                    fspan, folded=0 if folded is None else int(folded))
            if folded is not None:
                self._c["refreezes"].inc()

    def drain(self, max_steps: int = 100000) -> List[Response]:
        """Step until the queue is empty; returns responses in completion
        order."""
        out: List[Response] = []
        for _ in range(max_steps):
            if not self._pending:
                break
            out.extend(self.step())
        return out

    # ------------------------------------------------------------------
    # launch machinery
    # ------------------------------------------------------------------
    def _launch(self, bucket, live, parent=None) -> List[Response]:
        """One kernel launch over the unique rows (with retry/backoff and
        shard-failure failover), then scatter rows to every duplicate."""
        op = bucket[0]
        keys = list(live.keys())
        retries = {"n": 0}
        tr = self.obs.tracer

        def on_retry(attempt, exc):
            retries["n"] += 1
            self._c["retries"].inc()

        c0 = self.clock.now()
        t0 = self._timer() if self._timer is not None else None
        try:
            # scoped span: engine/resilience spans nest under it via the
            # tracer's current-span stack
            with tr.span("launch", parent=parent, op=op,
                         n_unique=len(keys)) as lspan:
                (result, info), _ = retry_call(
                    lambda: self._execute(op, [live[k][0] for k in keys]),
                    self.retry_policy, self.clock, self._rng,
                    on_retry=on_retry,
                )
                dt_real = (
                    self._timer() - t0 if self._timer is not None else 0.0
                )
                if dt_real:
                    # charge measured kernel service time to the virtual
                    # timeline (inside the span: launch duration = service)
                    self.clock.sleep(dt_real)
                tr.annotate(
                    lspan, backend=info["backend"],
                    degraded=info["degraded"], retries=retries["n"],
                )
        except InvalidQueryError:
            # poison in the batch: isolate per unique row so one bad
            # query cannot fail its batchmates
            return self._launch_isolated(op, live, retries, parent=parent)
        except Exception as exc:  # noqa: BLE001 - reported per request
            return [
                self._finish(r, Response(
                    id=r.id, op=r.op, tenant=r.tenant, status=FAILED,
                    retries=retries["n"], error=repr(exc),
                    latency_ms=(self.clock.now() - r.submit_s) * 1e3,
                ))
                for reqs in live.values() for r in reqs
            ]
        # virtual-clock runs: injected latency shows in the clock delta
        # (the timer charge was just added); real-clock runs: the clock
        # delta IS the measured elapsed time
        service_s = max(self.clock.now() - c0, dt_real)
        self._c["launches"].inc()
        self.predictor.observe(bucket, len(keys), service_s)

        with tr.span("merge", parent=parent, op=op):
            rows = self._slice_rows(op, result, len(keys))
            out: List[Response] = []
            for i, key in enumerate(keys):
                row = rows[i]
                if not info["degraded"]:
                    self._cache_put(key, row)
                for r in live[key]:
                    out.append(self._finish(r, self._respond_ok(
                        r, row, backend=info["backend"],
                        degraded=info["degraded"], retries=retries["n"],
                    )))
        return out

    def _launch_isolated(self, op, live, retries, parent=None
                         ) -> List[Response]:
        out: List[Response] = []
        tr = self.obs.tracer
        for key, reqs in live.items():
            try:
                with tr.span("launch", parent=parent, op=op, n_unique=1,
                             isolated=True):
                    (result, info), _ = retry_call(
                        lambda: self._execute(op, [reqs[0]]),
                        self.retry_policy, self.clock, self._rng,
                    )
            except Exception as exc:  # noqa: BLE001
                status = (
                    INVALID if isinstance(exc, InvalidQueryError)
                    else FAILED
                )
                for r in reqs:
                    out.append(self._finish(r, Response(
                        id=r.id, op=r.op, tenant=r.tenant, status=status,
                        error=repr(exc),
                        latency_ms=(
                            self.clock.now() - r.submit_s
                        ) * 1e3,
                    )))
                continue
            self._c["launches"].inc()
            row = self._slice_rows(op, result, 1)[0]
            if not info["degraded"]:
                self._cache_put(key, row)
            for r in reqs:
                out.append(self._finish(r, self._respond_ok(
                    r, row, backend=info["backend"],
                    degraded=info["degraded"], retries=retries["n"],
                )))
        return out

    def _execute(self, op: str, reps: Sequence[Request]):
        """One engine call over the representative requests' canonical
        payloads (all share the batch bucket, so kwargs agree).

        Launch shapes are normalized — batch rows pad to the next power
        of two and query rows to the fixed ``_qwidth`` — so a stream of
        arbitrary batch compositions compiles a bounded set of kernels.
        Pad rows are distinct absent-item queries (ids ``-2-i``: live
        negatives, never matched, never collapsed by downstream dedup),
        so they cost one empty descent each and the first ``len(reps)``
        output rows are untouched.
        """
        kw = reps[0].kwargs
        n = len(reps)
        npad = launch_pad(n)
        if op == "rule_search":
            width = max(self._qwidth,
                        max(len(r.canon[0]) for r in reps), 1)
            q = np.full((n, width), -1, np.int32)
            al = np.zeros((n,), np.int32)
            for i, r in enumerate(reps):
                row, a = r.canon
                q[i, : len(row)] = row
                al[i] = a
            # batch pow2 padding happens inside rule_search_batch's
            # whole-query dedup (ops.dedup_query_rows)
            return self.engine.query("rule_search_batch", q, al)
        if op == "top_k":
            width = max(self._qwidth,
                        max((len(r.canon) for r in reps), default=0), 1)
            mat = np.full((npad, width), -1, np.int32)
            for i, r in enumerate(reps):
                mat[i, : len(r.canon)] = r.canon
            # pad rows query an absent item -> empty [0, 0) range
            mat[n:, 0] = -2
            return self.engine.query(
                "top_k_rules_batch", mat,
                int(kw.get("k", 10)),
                metric=kw.get("metric", "confidence"),
                min_depth=int(kw.get("min_depth", 1)),
            )
        # distinct absent pad items keep the op's internal unique count
        # at exactly npad (a pow2) instead of an arbitrary n+1
        items = [r.canon for r in reps]
        items += [-2 - i for i in range(npad - n)]
        return self.engine.query(
            "rules_with", items,
            role=kw.get("role", "any"), k=int(kw.get("k", 10)),
            metric=kw.get("metric", "confidence"),
            min_depth=int(kw.get("min_depth", 1)),
        )

    @staticmethod
    def _slice_rows(op: str, result: Dict, n: int) -> List[Dict]:
        host = {k: np.asarray(v) for k, v in result.items()}
        return [
            {k: v[i] for k, v in host.items()} for i in range(n)
        ]

    # ------------------------------------------------------------------
    # responses / cache / deadlines
    # ------------------------------------------------------------------
    def _respond_ok(
        self, r: Request, row: Dict, backend: str,
        degraded: bool = False, cache_hit: bool = False, retries: int = 0,
    ) -> Response:
        return Response(
            id=r.id, op=r.op, tenant=r.tenant, status=OK, result=row,
            degraded=degraded, backend=backend, cache_hit=cache_hit,
            retries=retries,
            latency_ms=(self.clock.now() - r.submit_s) * 1e3,
        )

    def _finish(self, r: Request, resp: Response) -> Response:
        self._c[resp.status].inc()
        m = self.obs.metrics
        m.counter("serve.requests", tenant=r.tenant,
                  status=resp.status).inc()
        m.histogram("serve.latency_ms", op=r.op,
                    tenant=r.tenant).observe(resp.latency_ms)
        tr = self.obs.tracer
        if tr.enabled and r.span is not None:
            tr.end(r.qspan)
            tr.end(r.sspan)
            rsp = tr.start("respond", parent=r.span, status=resp.status)
            tr.end(rsp)
            tr.end(r.span, status=resp.status,
                   latency_ms=round(resp.latency_ms, 3),
                   backend=resp.backend, cache_hit=resp.cache_hit)
        self.responses[r.id] = resp
        return resp

    def _expire(self, done: List[Response]) -> None:
        now = self.clock.now()
        keep: deque = deque()
        while self._pending:
            r = self._pending.popleft()
            if now > r.expires_s():
                done.append(self._finish(r, Response(
                    id=r.id, op=r.op, tenant=r.tenant, status=TIMEOUT,
                    error="deadline expired in queue",
                    latency_ms=(now - r.submit_s) * 1e3,
                )))
            else:
                keep.append(r)
        self._pending = keep

    def _vkey(self, key) -> Tuple:
        """Cache address = engine version + canonical query key.

        The canonical key alone is NOT a stable address: it names the
        question, not the trie that answers it.  After an insert or a
        refreeze (epoch bump) or a shard failover, the same question has
        a different answer — versioning the key orphans every stale
        entry instead of serving a pre-insert row to a post-insert
        query.  Orphans age out of the LRU normally."""
        return (getattr(self.engine, "version", (0, 0)), key)

    def _cache_get(self, key):
        vkey = self._vkey(key)
        if vkey in self._cache:
            self._cache.move_to_end(vkey)
            return self._cache[vkey]
        return None

    def _cache_put(self, key, row) -> None:
        if self.cache_size <= 0:
            return
        vkey = self._vkey(key)
        self._cache[vkey] = row
        self._cache.move_to_end(vkey)
        while len(self._cache) > self.cache_size:
            self._cache.popitem(last=False)

    @property
    def cache_len(self) -> int:
        return len(self._cache)
