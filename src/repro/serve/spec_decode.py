"""Trie-backed n-gram speculative decoding (paper Eq. 1-4 at serve time).

The NgramTrie proposes a multi-token draft whose compound confidence (the
paper's product of node Confidences) gates the draft length; the model
verifies all draft tokens in ONE decode_step (tokens [b, k+1]) and accepts
the longest matching prefix — standard draft-verification with the Trie of
rules as the (free, training-less) draft model.

Single-sequence (b=1) host loop: serving-side batching composes this per
sequence; the verification call itself is batched across the draft.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.corpus_rules import NgramTrie
from repro.models.model import decode_step


def _greedy(logits: jax.Array) -> np.ndarray:
    return np.asarray(jnp.argmax(logits, axis=-1), np.int32)


def speculative_generate(
    cfg,
    params,
    cache,
    prompt: np.ndarray,            # [1, s0]
    trie: NgramTrie,
    n_tokens: int,
    max_draft: int = 4,
    min_confidence: float = 0.3,
) -> Tuple[np.ndarray, dict]:
    """Greedy speculative decoding; returns (tokens [1, n], stats)."""
    decode = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t), donate_argnums=(1,)
    )
    # prefill the prompt (cache consumes it in one step)
    logits, cache = decode(params, cache, jnp.asarray(prompt, jnp.int32))
    last = _greedy(logits[:, -1:])[0, 0]

    out: List[int] = []
    context = [int(t) for t in prompt[0]] + [int(last)]
    proposed = accepted = steps = 0
    while len(out) < n_tokens:
        out.append(int(last))
        if len(out) >= n_tokens:
            break
        tail = tuple(context[-(trie.n - 1):])
        draft, conf = trie.propose(
            tail, max_tokens=max_draft, min_confidence=min_confidence
        )
        steps += 1
        if draft:
            proposed += len(draft)
            block = np.array(
                [[last] + list(draft)], np.int32
            )                                       # [1, k+1]
            logits, cache = decode(
                params, cache, jnp.asarray(block)
            )
            preds = _greedy(logits)[0]              # model's next-token
            # accept longest prefix of draft matching the model
            n_ok = 0
            for i, d in enumerate(draft):
                if preds[i] == d:
                    n_ok += 1
                else:
                    break
            accepted += n_ok
            newly = list(draft[:n_ok]) + [int(preds[n_ok])]
            # cache now contains k+1 appended tokens; roll back the
            # rejected suffix by rewinding the cache position
            overshoot = len(draft) - n_ok
            if overshoot > 0:
                cache = _rewind(cache, overshoot)
            # accepted draft tokens are confirmed AND already in-cache:
            # emit them now; the model's own next token becomes `last`
            # (emitted at loop top, fed to the cache on the next block)
            for t in newly[:-1]:
                if len(out) < n_tokens:
                    out.append(t)
                context.append(t)
            last = newly[-1]
            context.append(int(last))
        else:
            block = np.array([[last]], np.int32)
            logits, cache = decode(params, cache, jnp.asarray(block))
            last = int(_greedy(logits[:, -1:])[0, 0])
            context.append(int(last))

    stats = {
        "proposed": proposed,
        "accepted": accepted,
        "accept_rate": accepted / proposed if proposed else 0.0,
        "verify_steps": steps,
    }
    return np.array([out[:n_tokens]], np.int32), stats


def _rewind(cache, k: int):
    """Rewind every per-layer position counter by k (rejected draft
    suffix).  Stale cache entries beyond the position are never attended
    (the causal mask is position-based), so no scrubbing is needed.

    NOTE: only attention/MLA caches are rewindable; SSM (Mamba) state has
    already advanced and would need snapshotting — spec-decode therefore
    targets attention-family architectures."""
    def fix(x):
        if hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.integer) \
                and x.ndim <= 1:
            return x - k
        return x

    return jax.tree.map(fix, cache)
