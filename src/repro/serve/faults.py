"""Deterministic fault injection for the serve loop.

Chaos testing a serving system needs faults that are *repeatable*: the
Nth kernel launch fails, a named shard answers late, a specific query is
poison.  ``FaultInjector`` is a counter-driven rule table with zero
randomness — the same program + the same injector config produces the
same fault sequence — and ``FaultyEngine`` is the seam that applies it:
a drop-in ``TrieQueryEngine`` wrapper that consults the injector before
and after every batched-op launch.  Both the fault-path tests
(``tests/test_serve_loop.py``) and the ``bench_serve`` lane drive their
failure scenarios through this one layer; production engines never see
it.

Faults:

* ``fail_nth_launch(n, shard)`` — the n-th launch (1-based, counted
  across all ops) raises ``trie_sharding.ShardFailure(shard)``; the
  resilience ladder must demote and re-run in-flight work.
* ``fail_transient(n)`` — the n-th launch raises a retryable
  ``TransientBackendError`` (``is_retryable`` → True); the scheduler's
  backoff loop must absorb it.
* ``slow_shard(shard, delay_s)`` — every launch while ``shard`` is slow
  charges ``delay_s`` extra seconds to the injected clock, training
  ``ShardHealth``'s straggler detector.
* ``poison_payload(predicate)`` — launches whose batch contains a
  payload matching ``predicate`` raise ``InvalidQueryError``; the
  scheduler must isolate the poison row, not fail the batch.

``zipfian_workload`` lives here too: the shared multi-tenant traffic
generator (Zipf-ranked query popularity — heavy duplication, like real
rule-serving traffic) replayed by both the tests and ``bench_serve``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.kernels.ops import InvalidQueryError, TransientBackendError


# ----------------------------------------------------------------------
# the injector (counter-driven, zero randomness)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class _Rule:
    kind: str                      # "shard_fail" | "transient" | "poison"
    at_launch: int = 0             # 1-based launch counter match (0 = any)
    shard: int = 0
    predicate: Optional[Callable] = None
    fired: int = 0
    max_fires: int = 1


class FaultInjector:
    """Deterministic fault rule table consulted by ``FaultyEngine``."""

    def __init__(self):
        self.launches = 0           # completed + faulted launch attempts
        self.events: List[dict] = []
        self._rules: List[_Rule] = []
        self._slow: Dict[int, float] = {}   # shard -> extra seconds

    # -- configuration ------------------------------------------------
    def fail_nth_launch(
        self, n: int, shard: int = 0, times: int = 1
    ) -> "FaultInjector":
        self._rules.append(
            _Rule("shard_fail", at_launch=int(n), shard=int(shard),
                  max_fires=int(times))
        )
        return self

    def fail_transient(self, n: int, times: int = 1) -> "FaultInjector":
        self._rules.append(
            _Rule("transient", at_launch=int(n), max_fires=int(times))
        )
        return self

    def slow_shard(self, shard: int, delay_s: float) -> "FaultInjector":
        self._slow[int(shard)] = float(delay_s)
        return self

    def clear_slow(self, shard: int) -> "FaultInjector":
        self._slow.pop(int(shard), None)
        return self

    def poison_payload(
        self, predicate: Callable[[object], bool], times: int = 1
    ) -> "FaultInjector":
        """Launches whose batch payload satisfies ``predicate`` raise
        ``InvalidQueryError`` — the poison-query fault."""
        self._rules.append(
            _Rule("poison", predicate=predicate, max_fires=int(times))
        )
        return self

    # -- the hooks FaultyEngine calls ---------------------------------
    def before_launch(self, op: str, payload) -> None:
        """Counts the launch attempt, then raises if any rule matches."""
        self.launches += 1
        for rule in self._rules:
            if rule.fired >= rule.max_fires:
                continue
            if rule.kind in ("shard_fail", "transient"):
                if rule.at_launch != self.launches:
                    continue
                rule.fired += 1
                self.events.append({
                    "kind": rule.kind, "launch": self.launches, "op": op,
                    "shard": rule.shard,
                })
                if rule.kind == "shard_fail":
                    from repro.distributed.trie_sharding import (
                        ShardFailure,
                    )

                    raise ShardFailure(
                        rule.shard,
                        f"injected: launch {self.launches} ({op})",
                    )
                raise TransientBackendError(
                    f"injected transient: launch {self.launches} ({op})"
                )
            if rule.kind == "poison" and rule.predicate(payload):
                rule.fired += 1
                self.events.append({
                    "kind": "poison", "launch": self.launches, "op": op,
                })
                raise InvalidQueryError(
                    f"injected poison query in launch {self.launches} "
                    f"({op})"
                )

    def extra_latency(self) -> float:
        """Slow-shard latency charged to this launch (every launch
        touches every shard under ``shard_map``, so any slow shard slows
        the whole launch — the straggler effect)."""
        return sum(self._slow.values())

    def shard_latency(self, shard: int) -> float:
        """Per-shard injected latency — the simulated per-shard timing
        probe ``FaultyEngine`` feeds into ``ShardHealth``."""
        return self._slow.get(int(shard), 0.0)


class FaultyEngine:
    """``TrieQueryEngine`` wrapper routing every launch through a
    ``FaultInjector``.  ``clock`` (usually a ``VirtualClock``) is charged
    the injected slow-shard latency so deadline/straggler behavior is
    observable without real sleeping."""

    def __init__(
        self, engine, injector: FaultInjector, clock=None, health=None,
    ):
        self.engine = engine
        self.injector = injector
        self.clock = clock
        # optional ShardHealth: each launch feeds every shard's injected
        # latency into its straggler detector — the simulation stand-in
        # for real per-shard launch profiling.  Note the detector's EWMA
        # baseline comes from the FIRST observation, so a shard slowed
        # before any clean launch is its own baseline and never flags.
        self.health = health

    # passthroughs the resilience ladder reads
    @property
    def obs(self):
        """Observability passthrough: the wrapped engine owns the spans
        (a fault wrapper adds no stage of its own)."""
        return getattr(self.engine, "obs", None)

    @obs.setter
    def obs(self, value) -> None:
        self.engine.obs = value

    @property
    def frozen(self):
        return self.engine.frozen

    @property
    def plan(self):
        return self.engine.plan

    @property
    def stream(self):
        return getattr(self.engine, "stream", None)

    @property
    def epoch(self) -> int:
        return int(getattr(self.engine, "epoch", 0))

    # streaming mutations bypass fault injection: they are host-side
    # bookkeeping, not launches
    def insert(self, sequences, support, confidence, lift) -> int:
        return self.engine.insert(sequences, support, confidence, lift)

    def maybe_refreeze(self):
        return self.engine.maybe_refreeze()

    @property
    def backend(self) -> str:
        return self.engine.backend

    @property
    def n_shards(self) -> int:
        return self.engine.n_shards

    def _launch(self, op: str, payload, fn):
        self.injector.before_launch(op, payload)
        out = fn()
        delay = self.injector.extra_latency()
        if delay and self.clock is not None:
            self.clock.sleep(delay)
        if self.health is not None:
            for shard in range(self.engine.n_shards):
                self.health.record_launch(
                    shard, self.injector.shard_latency(shard)
                )
        return out

    def rule_search_batch(self, queries, ant_len=None):
        return self._launch(
            "rule_search_batch", queries,
            lambda: self.engine.rule_search_batch(queries, ant_len),
        )

    def top_k_rules_batch(self, prefixes, k, **kw):
        return self._launch(
            "top_k_rules_batch", prefixes,
            lambda: self.engine.top_k_rules_batch(prefixes, k, **kw),
        )

    def rules_with(self, items, **kw):
        return self._launch(
            "rules_with", items,
            lambda: self.engine.rules_with(items, **kw),
        )


# ----------------------------------------------------------------------
# zipfian multi-tenant traffic
# ----------------------------------------------------------------------
def zipfian_workload(
    frozen,
    n_requests: int,
    seed: int = 0,
    s: float = 1.2,
    n_tenants: int = 4,
    op_mix: Tuple[float, float, float] = (0.5, 0.3, 0.2),
    deadline_ms: Tuple[float, ...] = (50.0, 200.0, 1000.0),
    arrival_rate: Optional[float] = None,
) -> List[dict]:
    """``n_requests`` request dicts replaying skewed serving traffic.

    Query *popularity* is Zipf-ranked (popularity rank r drawn with
    probability ∝ r^-s) over a pool of distinct queries per op, so a
    small hot set dominates — exactly the duplication profile the
    whole-query dedup + LRU cache exist for.  Ops mix over
    (rule_search, top_k, rules_with) by ``op_mix``; tenants round-robin
    a seeded permutation; deadlines cycle ``deadline_ms`` per tenant.
    With ``arrival_rate`` (requests/second) each dict carries an
    ``arrival_s`` drawn from a seeded Poisson process; otherwise all
    arrive at 0.

    Returns plain dicts (op / payload / kwargs / tenant / deadline_ms /
    arrival_s) — the scheduler's ``Request`` constructor consumes them.
    """
    rng = np.random.default_rng(seed)
    n_items = int(np.asarray(frozen.item_offsets).shape[0] - 1)
    # distinct-query pools per op, drawn once from real trie paths
    pool_n = max(min(64, n_requests), 1)
    edge_item = np.asarray(frozen.edge_item, np.int64)
    edge_parent = np.asarray(frozen.edge_parent, np.int64)
    edge_child = np.asarray(frozen.edge_child, np.int64)

    def random_path():
        """A real root-to-node path (item sequence) of depth 1-4."""
        items = []
        node = 0
        for _ in range(int(rng.integers(1, 5))):
            mask = edge_parent == node
            if not mask.any():
                break
            j = int(rng.choice(np.flatnonzero(mask)))
            items.append(int(edge_item[j]))
            node = int(edge_child[j])
        return items or [int(rng.integers(0, max(n_items, 1)))]

    search_pool = []
    for _ in range(pool_n):
        path = random_path()
        cut = int(rng.integers(1, len(path) + 1)) if len(path) > 1 else 1
        search_pool.append((tuple(path[:cut]), tuple(path[cut:])))
    topk_pool = [tuple(random_path()[:2]) for _ in range(pool_n)]
    item_pool = [
        int(rng.integers(0, max(n_items, 1))) for _ in range(pool_n)
    ]

    # Zipf popularity ranks over each pool
    ranks = np.arange(1, pool_n + 1, dtype=np.float64)
    pz = ranks ** -s
    pz /= pz.sum()
    ops = rng.choice(3, size=n_requests, p=np.asarray(op_mix))
    picks = rng.choice(pool_n, size=n_requests, p=pz)
    tenants = rng.permutation(n_tenants)
    arrivals = np.zeros(n_requests)
    if arrival_rate:
        arrivals = np.cumsum(
            rng.exponential(1.0 / arrival_rate, size=n_requests)
        )
    out: List[dict] = []
    for i in range(n_requests):
        tenant = int(tenants[i % n_tenants])
        req = {
            "tenant": f"tenant-{tenant}",
            "deadline_ms": float(deadline_ms[tenant % len(deadline_ms)]),
            "arrival_s": float(arrivals[i]),
        }
        if ops[i] == 0:
            ant, con = search_pool[picks[i]]
            # depth-1 paths leave the consequent empty; re-ask the path
            # item as its own consequent (a miss — real traffic has them)
            con = con or ant
            req.update(op="rule_search", payload=(list(ant), list(con)),
                       kwargs={})
        elif ops[i] == 1:
            req.update(
                op="top_k", payload=list(topk_pool[picks[i]]),
                kwargs={"k": 8, "metric": "confidence"},
            )
        else:
            req.update(
                op="rules_with", payload=item_pool[picks[i]],
                kwargs={"role": "any", "k": 8, "metric": "lift"},
            )
        out.append(req)
    return out
