"""ARM over tokenized corpora — the paper's structure as a data feature.

Two integrations (DESIGN.md §4):

1. ``mine_corpus_rules``: token co-occurrence windows are transactions
   (items = token ids); the resulting Trie of rules answers corpus
   analytics — high-confidence long paths are boilerplate/template
   detectors used for curation.

2. ``NgramTrie``: the SAME prefix-trie structure over *ordered* n-grams
   (identity item order instead of frequency order).  Node confidence is
   exactly P(next-token | prefix), and the paper's compound-consequent
   product (Eq. 1-4) is the probability of a multi-token draft — which is
   what ``repro.serve.spec_decode`` uses as a speculative-decoding
   proposer.
"""
from __future__ import annotations

from collections import Counter
from typing import Iterable, List, Optional, Sequence, Tuple

import numpy as np

from repro.arm.transactions import TransactionDB
from repro.core.builder import BuildResult, build_trie_of_rules
from repro.core.trie import TrieOfRules


def windows_to_db(
    token_rows: np.ndarray, window: int = 8, stride: int = 4,
    vocab_size: Optional[int] = None,
) -> TransactionDB:
    """Sliding windows over token rows → transaction DB (items=token ids)."""
    txs: List[set] = []
    vmax = 0
    for row in token_rows:
        row = [int(t) for t in row if int(t) >= 0]
        for start in range(0, max(1, len(row) - window + 1), stride):
            w = row[start : start + window]
            if w:
                txs.append(set(w))
                vmax = max(vmax, max(w))
    n_items = vocab_size if vocab_size is not None else vmax + 1
    return TransactionDB(txs, n_items=n_items)


def mine_corpus_rules(
    token_rows: np.ndarray,
    min_support: float = 0.01,
    window: int = 8,
    stride: int = 4,
    vocab_size: Optional[int] = None,
    miner: str = "fpgrowth",
) -> Tuple[BuildResult, TransactionDB]:
    db = windows_to_db(token_rows, window, stride, vocab_size)
    return build_trie_of_rules(db, min_support, miner=miner), db


def boilerplate_paths(
    result: BuildResult, min_depth: int = 4, min_confidence: float = 0.8
) -> List[Tuple[Tuple[int, ...], float]]:
    """High-confidence long paths = template/boilerplate detectors."""
    out = []
    for path, node in result.trie.all_paths():
        if node.depth >= min_depth and node.confidence >= min_confidence:
            out.append((path, node.confidence))
    return sorted(out, key=lambda x: (-len(x[0]), -x[1]))


class NgramTrie:
    """Trie of rules over ORDERED token n-grams (identity item order).

    Construction annotates Support/Confidence directly from prefix counts
    (Step 3 of the paper, with the transaction-DB oracle replaced by the
    n-gram count oracle — counts are exact for ordered prefixes).
    """

    def __init__(self, n: int = 4):
        self.n = n
        self.trie = TrieOfRules(item_order=None)  # identity order
        self.total = 0

    def fit(self, token_rows: Iterable[Sequence[int]]) -> "NgramTrie":
        counts: Counter = Counter()
        for row in token_rows:
            row = [int(t) for t in row]
            self.total += max(0, len(row) - self.n + 1)
            for i in range(len(row) - self.n + 1):
                gram = tuple(row[i : i + self.n])
                counts[gram] += 1
        # insert and annotate from prefix counts
        prefix_counts: Counter = Counter()
        for gram, c in counts.items():
            for k in range(1, self.n + 1):
                prefix_counts[gram[:k]] += c
        for gram in counts:
            node = self.trie.insert(gram)
        for path, node in self.trie.all_paths():
            c = prefix_counts[path]
            parent_c = (
                prefix_counts[path[:-1]] if len(path) > 1 else self.total
            )
            node.support = c / max(self.total, 1)
            node.confidence = c / max(parent_c, 1)
            item_c = prefix_counts[(path[-1],)]
            node.lift = (
                node.confidence / (item_c / max(self.total, 1))
                if item_c else 0.0
            )
        return self

    def propose(
        self,
        context_tail: Sequence[int],
        max_tokens: int = 4,
        min_confidence: float = 0.3,
    ) -> Tuple[List[int], float]:
        """Greedy highest-confidence walk from the (n-1)-token context:
        returns (draft tokens, compound confidence = Eq. 1 product)."""
        node = self.trie.find_path(tuple(context_tail))
        if node is None:
            return [], 0.0
        draft: List[int] = []
        conf = 1.0
        for _ in range(max_tokens):
            if not node.children:
                break
            child = max(node.children.values(), key=lambda c: c.confidence)
            if conf * child.confidence < min_confidence:
                break
            conf *= child.confidence
            draft.append(child.item)
            node = child
        return draft, conf
