"""Byte-level tokenizer (offline container: no external vocabs).

256 byte tokens + 3 specials.  Deterministic, reversible, and adequate for
the ~100M-parameter end-to-end training example; production swaps in a
learned BPE via the same interface.
"""
from __future__ import annotations

from typing import Iterable, List

PAD_ID = 256
BOS_ID = 257
EOS_ID = 258
VOCAB_SIZE = 259


class ByteTokenizer:
    pad_id = PAD_ID
    bos_id = BOS_ID
    eos_id = EOS_ID
    vocab_size = VOCAB_SIZE

    def encode(self, text: str, add_bos: bool = True,
               add_eos: bool = True) -> List[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [BOS_ID] + ids
        if add_eos:
            ids = ids + [EOS_ID]
        return ids

    def decode(self, ids: Iterable[int]) -> str:
        data = bytes(i for i in ids if i < 256)
        return data.decode("utf-8", errors="replace")
