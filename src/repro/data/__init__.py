"""Data pipeline: tokenization, packing, deterministic sharded batches,
and ARM-over-corpus integration (the paper's structure as a data feature).
"""
from .tokenizer import ByteTokenizer
from .pipeline import PipelineConfig, TokenPipeline, synthetic_corpus
from .corpus_rules import NgramTrie, mine_corpus_rules

__all__ = [
    "ByteTokenizer",
    "PipelineConfig",
    "TokenPipeline",
    "synthetic_corpus",
    "NgramTrie",
    "mine_corpus_rules",
]
