"""Deterministic, stateless-seekable, sharded token pipeline.

Restart semantics (fault tolerance): ``batch_at(step)`` is a pure function
of (seed, step), so resuming from a checkpoint at step N reproduces the
exact batch stream with no iterator state to persist.  Documents are packed
into fixed-length rows with ``segment_ids`` so attention never crosses
document boundaries (the model masks on them).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from .tokenizer import ByteTokenizer


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seq_len: int
    global_batch: int
    seed: int = 0
    pack: bool = True


_WORDS = (
    "the of a to in rule trie mining support confidence lift node path "
    "data set tree fast search market basket item retail store apple "
    "bread milk beer diaper cheese wine fish rice tea coffee sugar salt "
    "paper code model train serve batch shard mesh pod chip kernel"
).split()


def synthetic_corpus(n_docs: int, seed: int = 0,
                     lo: int = 64, hi: int = 512) -> List[str]:
    """Offline corpus with Zipfian word draws + recurring boilerplate
    templates (gives the corpus-rule miner real structure to find)."""
    rng = np.random.RandomState(seed)
    probs = 1.0 / np.arange(1, len(_WORDS) + 1, dtype=np.float64)
    probs /= probs.sum()
    boiler = "terms and conditions apply see store for details"
    docs = []
    for _ in range(n_docs):
        n = rng.randint(lo, hi)
        words = [
            _WORDS[i] for i in rng.choice(len(_WORDS), size=n, p=probs)
        ]
        if rng.rand() < 0.3:
            k = rng.randint(0, max(1, n - 1))
            words[k:k] = boiler.split()
        docs.append(" ".join(words))
    return docs


class TokenPipeline:
    """Packs a tokenized corpus into deterministic training batches."""

    def __init__(self, docs: Sequence[str], cfg: PipelineConfig,
                 tokenizer: Optional[ByteTokenizer] = None):
        self.cfg = cfg
        self.tok = tokenizer or ByteTokenizer()
        self._rows, self._segs = self._pack(docs)

    def _pack(self, docs):
        s = self.cfg.seq_len + 1   # +1 for the shifted labels
        rows: List[np.ndarray] = []
        segs: List[np.ndarray] = []
        cur = np.full((s,), self.tok.pad_id, np.int32)
        seg = np.zeros((s,), np.int32)
        fill = 0
        seg_id = 1
        for doc in docs:
            ids = self.tok.encode(doc)
            i = 0
            while i < len(ids):
                take = min(len(ids) - i, s - fill)
                cur[fill : fill + take] = ids[i : i + take]
                seg[fill : fill + take] = seg_id
                fill += take
                i += take
                if fill == s:
                    rows.append(cur.copy())
                    segs.append(seg.copy())
                    cur[:] = self.tok.pad_id
                    seg[:] = 0
                    fill = 0
                    if not self.cfg.pack:
                        break
            seg_id += 1
        if fill > 0:
            rows.append(cur.copy())
            segs.append(seg.copy())
        return np.stack(rows), np.stack(segs)

    @property
    def n_rows(self) -> int:
        return self._rows.shape[0]

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of (seed, step): the fault-tolerance contract."""
        rng = np.random.RandomState(
            (self.cfg.seed * 1_000_003 + step) % (2**31 - 1)
        )
        idx = rng.randint(0, self.n_rows, size=self.cfg.global_batch)
        rows = self._rows[idx]
        segs = self._segs[idx]
        return {
            "tokens": rows[:, :-1],
            "labels": rows[:, 1:],
            "segment_ids": segs[:, :-1],
            "loss_mask": (segs[:, 1:] > 0).astype(np.float32),
        }

    def batches(self, start_step: int = 0) -> Iterator[Dict]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1
