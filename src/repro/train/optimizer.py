"""Optimizers in pure JAX: AdamW and factored Adafactor.

``pick_optimizer(cfg)`` selects Adafactor for ≥100B-parameter models so the
optimizer state stays O(sum-of-dims) instead of O(params) — the standard
large-model memory recipe (DESIGN.md §6).  Both optimizers expose
``init(params) → state`` and ``update(grads, state, params, step) →
(new_params, new_state)`` and ``state_axes(param_axes)`` so the state
shards exactly like its parameters.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"          # "adamw" | "adafactor"
    lr: float = 3e-4
    warmup_steps: int = 100
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_schedule(ocfg: OptConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(ocfg.warmup_steps, 1))
    return ocfg.lr * warm


def global_norm(tree) -> jax.Array:
    leaves = [
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    ]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads), norm


# ----------------------------------------------------------------------
# AdamW
# ----------------------------------------------------------------------
class AdamW:
    def __init__(self, ocfg: OptConfig):
        self.cfg = ocfg

    def init(self, params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def state_axes(self, param_axes):
        return {"m": param_axes, "v": param_axes}

    def update(self, grads, state, params, step):
        c = self.cfg
        grads, gnorm = clip_by_global_norm(grads, c.clip_norm)
        lr = lr_schedule(c, step)
        t = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - c.b1 ** t
        bc2 = 1.0 - c.b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = c.b1 * m + (1 - c.b1) * g
            v = c.b2 * v + (1 - c.b2) * g * g
            mh = m / bc1
            vh = v / bc2
            step_ = mh / (jnp.sqrt(vh) + c.eps)
            if p.ndim >= 2:  # decoupled weight decay on matrices only
                step_ = step_ + c.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_params = jax.tree.map(lambda o: o[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v}, gnorm


# ----------------------------------------------------------------------
# Adafactor (factored second moment, no first moment)
# ----------------------------------------------------------------------
class Adafactor:
    def __init__(self, ocfg: OptConfig):
        self.cfg = ocfg

    def _factored(self, p) -> bool:
        return p.ndim >= 2

    def init(self, params):
        def st(p):
            if self._factored(p):
                return {
                    "vr": jnp.zeros(p.shape[:-1], jnp.float32),
                    "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                    jnp.float32),
                }
            return {"v": jnp.zeros(p.shape, jnp.float32)}

        return {"f": jax.tree.map(st, params)}

    def state_axes(self, param_axes):
        def ax(axes):
            axes = tuple(axes)
            if len(axes) >= 2:
                return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
            return {"v": axes}

        is_axes = lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x
        )
        return {"f": jax.tree.map(ax, param_axes, is_leaf=is_axes)}

    def update(self, grads, state, params, step):
        c = self.cfg
        grads, gnorm = clip_by_global_norm(grads, c.clip_norm)
        lr = lr_schedule(c, step)
        beta = 1.0 - (step + 1.0) ** -0.8   # t^-0.8 decay (Adafactor paper)

        def upd(g, st, p):
            g = g.astype(jnp.float32)
            g2 = g * g + 1e-30
            if self._factored(p):
                vr = beta * st["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * st["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                rfac = jax.lax.rsqrt(
                    vr / jnp.maximum(
                        jnp.mean(vr, axis=-1, keepdims=True), 1e-30
                    ) + c.eps
                )
                cfac = jax.lax.rsqrt(vc + c.eps)
                step_ = g * rfac[..., None] * cfac[..., None, :]
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta * st["v"] + (1 - beta) * g2
                step_ = g * jax.lax.rsqrt(v + c.eps)
                new_st = {"v": v}
            # RMS-clip the update (Adafactor d=1.0)
            rms = jnp.sqrt(jnp.mean(step_ * step_) + 1e-30)
            step_ = step_ / jnp.maximum(1.0, rms)
            if p.ndim >= 2:
                step_ = step_ + c.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step_).astype(p.dtype), new_st

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["f"])
        outs = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_params = tdef.unflatten([o[0] for o in outs])
        new_state = {"f": tdef.unflatten([o[1] for o in outs])}
        return new_params, new_state, gnorm


def pick_optimizer(model_cfg, ocfg: Optional[OptConfig] = None):
    """Adafactor at ≥100B params, AdamW below (overridable)."""
    if ocfg is None:
        ocfg = OptConfig()
    if ocfg.name == "adafactor":
        return Adafactor(ocfg)
    if ocfg.name == "adamw":
        from repro.models import count_params_analytic

        if count_params_analytic(model_cfg) >= 100e9:
            return Adafactor(dataclasses.replace(ocfg, name="adafactor"))
        return AdamW(ocfg)
    raise ValueError(ocfg.name)
