"""Training substrate: optimizers, train step, checkpointing, elasticity."""
from .optimizer import AdamW, Adafactor, OptConfig, pick_optimizer
from .train_step import make_train_step

__all__ = [
    "AdamW", "Adafactor", "OptConfig", "pick_optimizer", "make_train_step",
]
