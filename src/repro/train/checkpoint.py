"""Fault-tolerant checkpointing: versioned, atomic, hash-verified, async.

Layout:  <dir>/step_<N>/arrays.npz + manifest.json(sha256, treedef, step)
Writes go to ``step_<N>.tmp`` and are renamed only after fsync — a crash
mid-save can never corrupt the latest checkpoint.  ``restore_latest``
verifies content hashes and falls back to the previous step on corruption.
A background thread makes ``save_async`` non-blocking for the train loop.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

import jax


def _flatten(tree) -> Tuple[List[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def _tree_sig(treedef) -> str:
    return hashlib.sha256(str(treedef).encode()).hexdigest()[:16]


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None):
    """Atomic checkpoint write; returns the final directory path."""
    leaves, treedef = _flatten(tree)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    arrays_path = os.path.join(tmp, "arrays.npz")
    np.savez(arrays_path, *leaves)
    with open(arrays_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    manifest = {
        "step": step,
        "n_leaves": len(leaves),
        "sha256": digest,
        "treedef": _tree_sig(treedef),
        "extra": extra or {},
    }
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """One background writer thread; ``wait()`` joins outstanding saves."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save_async(self, step: int, tree, extra=None):
        self.wait()
        host_tree = jax.tree.map(np.asarray, tree)  # snapshot off-device

        def work():
            try:
                save(self.ckpt_dir, step, host_tree, extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = list_steps(self.ckpt_dir)
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.ckpt_dir, f"step_{s:08d}"),
                ignore_errors=True,
            )


def list_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return sorted(out)


def _load_step(ckpt_dir: str, step: int, tree_like):
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays_path = os.path.join(path, "arrays.npz")
    with open(arrays_path, "rb") as f:
        digest = hashlib.sha256(f.read()).hexdigest()
    if digest != manifest["sha256"]:
        raise IOError(f"checksum mismatch at step {step}")
    leaves_like, treedef = jax.tree.flatten(tree_like)
    if _tree_sig(treedef) != manifest["treedef"]:
        raise IOError(f"treedef mismatch at step {step}")
    with np.load(arrays_path) as data:
        leaves = [data[f"arr_{i}"] for i in range(manifest["n_leaves"])]
    restored = [
        np.asarray(l).astype(like.dtype).reshape(like.shape)
        for l, like in zip(leaves, leaves_like)
    ]
    return treedef.unflatten(restored), manifest


def restore_latest(ckpt_dir: str, tree_like):
    """Newest valid checkpoint, falling back past corrupted ones.

    Returns (tree, manifest) or (None, None) when nothing restorable."""
    for step in reversed(list_steps(ckpt_dir)):
        try:
            return _load_step(ckpt_dir, step, tree_like)
        except Exception:
            continue
    return None, None
