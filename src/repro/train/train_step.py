"""Train-step factory: grads (+optional microbatch accumulation) →
clip → optimizer → new params.

Microbatch accumulation runs as a ``lax.scan`` over the leading split of
the batch, which both bounds activation memory and — because XLA overlaps
the per-microbatch gradient reduce-scatter with the next microbatch's
compute — is the standard collective/compute overlap trick at scale.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import loss_fn


def make_train_step(
    cfg,
    optimizer,
    microbatches: int = 1,
    grad_transform: Optional[Callable] = None,
):
    """Returns step(params, opt_state, batch, step_idx) → (params,
    opt_state, metrics).  ``grad_transform`` hooks gradient compression."""

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch), has_aux=True
        )(params)
        return loss, metrics, grads

    def step(params, opt_state, batch, step_idx):
        if microbatches > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])

            mb = jax.tree.map(split, batch)

            def body(acc, one):
                loss, metrics, grads = grads_of(params, one)
                acc_g, acc_l = acc
                acc_g = jax.tree.map(jnp.add, acc_g, grads)
                return (acc_g, acc_l + loss), metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            (gsum, lsum), metrics_stack = jax.lax.scan(
                body, (zero, jnp.zeros((), jnp.float32)), mb
            )
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
            metrics = jax.tree.map(
                lambda m: jnp.mean(m, axis=0), metrics_stack
            )
        else:
            loss, metrics, grads = grads_of(params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)

        params, opt_state, gnorm = optimizer.update(
            grads, opt_state, params, step_idx
        )
        metrics = dict(metrics)
        metrics["loss"] = loss
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return step
