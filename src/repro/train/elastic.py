"""Straggler detection and elastic re-meshing (large-scale runnability).

- ``StragglerDetector``: per-step wall-time EWMA + deviation score; flags
  sustained slowdowns (the signal a real fleet uses to evict a slow host).
- ``remesh_state``: reshard a (params, opt_state) pytree onto a new mesh —
  the elastic-scaling primitive used after shrinking/growing the device
  pool.  Works from host-replicated arrays (restored checkpoints), so the
  recovery path is checkpoint → remesh → resume.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import logical_to_spec


@dataclasses.dataclass
class StragglerDetector:
    alpha: float = 0.1            # EWMA weight
    threshold: float = 2.0        # flag when step > threshold × EWMA
    patience: int = 3             # consecutive slow steps before firing
    _ewma: Optional[float] = None
    _var: float = 0.0
    _slow_streak: int = 0
    events: List[dict] = dataclasses.field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Returns True when a sustained straggle is detected."""
        if self._ewma is None:
            self._ewma = seconds
            return False
        slow = seconds > self.threshold * self._ewma
        if slow:
            self._slow_streak += 1
        else:
            self._slow_streak = 0
            self._ewma = (
                (1 - self.alpha) * self._ewma + self.alpha * seconds
            )
        if self._slow_streak >= self.patience:
            self.events.append(
                {"step": step, "seconds": seconds, "ewma": self._ewma}
            )
            self._slow_streak = 0
            return True
        return False


def remesh_state(tree, axes_tree, new_mesh: Mesh):
    """Re-place every leaf onto ``new_mesh`` with its logical sharding.

    The leaves may live on any (old) mesh or on host; ``jax.device_put``
    performs the resharding transfer."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )

    def place(x, axes):
        spec = logical_to_spec(tuple(axes), new_mesh, x.shape)
        return jax.device_put(x, NamedSharding(new_mesh, spec))

    return jax.tree.map(place, tree, axes_tree, is_leaf=None)
