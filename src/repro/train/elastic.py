"""Straggler detection and elastic re-meshing (large-scale runnability).

- ``StragglerDetector``: per-step wall-time EWMA + deviation score; flags
  sustained slowdowns (the signal a real fleet uses to evict a slow host).
  The implementation now lives in ``distributed.health`` — the serve
  loop's ``ShardHealth`` reuses the same detector for slow-shard
  demotion — and is re-exported here for existing call sites.
- ``remesh_state``: reshard a (params, opt_state) pytree onto a new mesh —
  the elastic-scaling primitive used after shrinking/growing the device
  pool.  Works from host-replicated arrays (restored checkpoints), so the
  recovery path is checkpoint → remesh → resume.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.health import StragglerDetector
from repro.distributed.sharding import logical_to_spec

__all__ = ["StragglerDetector", "remesh_state"]


def remesh_state(tree, axes_tree, new_mesh: Mesh):
    """Re-place every leaf onto ``new_mesh`` with its logical sharding.

    The leaves may live on any (old) mesh or on host; ``jax.device_put``
    performs the resharding transfer."""
    is_axes = lambda x: isinstance(x, tuple) and all(
        isinstance(a, (str, type(None))) for a in x
    )

    def place(x, axes):
        spec = logical_to_spec(tuple(axes), new_mesh, x.shape)
        return jax.device_put(x, NamedSharding(new_mesh, spec))

    return jax.tree.map(place, tree, axes_tree, is_leaf=None)
