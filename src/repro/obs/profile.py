"""Kernel-launch profiling: one timing ring per op.

``repro.kernels.ops`` calls :func:`KernelProfiler.record` around its
kernel dispatches (behind a single ``enabled`` check — disabled cost is
one attribute read) with the launch geometry that actually matters for
perf triage: launch rows, block shape, pad factor (padded/live rows),
and shard count, plus host wall time with the result blocked-on so the
timing is honest even under async dispatch.

Each op keeps a fixed-capacity ring of :class:`LaunchRecord`; records
additionally fan out to

* an optional :class:`~repro.obs.metrics.MetricsRegistry`
  (``kernel.launch_ms{op=...}`` histograms + ``kernel.launches`` counters),
* registered observers — the scheduler registers one that feeds
  ``LaunchPredictor.observe(("kernel", op), rows, seconds)`` so measured
  kernel time becomes a queryable prediction bucket alongside the
  service-time buckets the deadline shaper uses.

The module-level :data:`kernel_profiler` singleton is what ``ops.py``
consults; ``enabled_scope`` scopes activation (benches, tests) without
leaking global state.
"""
from __future__ import annotations

import weakref
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Callable, Deque, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class LaunchRecord:
    op: str
    rows: int                # launch batch rows (post-pad)
    shape: Tuple[int, ...]   # launch block shape
    pad_factor: float        # rows / live rows (>= 1.0)
    n_shards: int
    seconds: float           # host wall time, result blocked-on


class KernelProfiler:
    """Per-op timing rings + observer fan-out.  Off by default."""

    def __init__(self, capacity: int = 256):
        self.enabled = False
        self.capacity = capacity
        self.metrics = None  # Optional[MetricsRegistry]
        self._rings: Dict[str, Deque[LaunchRecord]] = {}
        self._observers: List[weakref.ref] = []

    # -- lifecycle ------------------------------------------------------
    def enable(self, metrics=None) -> None:
        self.enabled = True
        if metrics is not None:
            self.metrics = metrics

    def disable(self) -> None:
        self.enabled = False
        self.metrics = None

    @contextmanager
    def enabled_scope(self, metrics=None):
        prev_enabled, prev_metrics = self.enabled, self.metrics
        self.enable(metrics=metrics)
        try:
            yield self
        finally:
            self.enabled, self.metrics = prev_enabled, prev_metrics

    def clear(self) -> None:
        self._rings.clear()

    # -- observers (weakly held so schedulers don't leak) ---------------
    def add_observer(self, fn: Callable[[LaunchRecord], None]) -> None:
        ref = (weakref.WeakMethod(fn) if hasattr(fn, "__self__")
               else weakref.ref(fn))
        self._observers.append(ref)

    def _notify(self, rec: LaunchRecord) -> None:
        live = []
        for ref in self._observers:
            fn = ref()
            if fn is None:
                continue  # observer owner died; prune
            live.append(ref)
            fn(rec)
        self._observers = live

    # -- recording ------------------------------------------------------
    def record(
        self,
        op: str,
        *,
        rows: int,
        shape: Tuple[int, ...],
        seconds: float,
        pad_factor: float = 1.0,
        n_shards: int = 1,
    ) -> None:
        rec = LaunchRecord(op, int(rows), tuple(int(s) for s in shape),
                           float(pad_factor), int(n_shards), float(seconds))
        ring = self._rings.get(op)
        if ring is None:
            ring = self._rings[op] = deque(maxlen=self.capacity)
        ring.append(rec)
        m = self.metrics
        if m is not None:
            m.counter("kernel.launches", op=op).inc()
            m.histogram("kernel.launch_ms", op=op).observe(rec.seconds * 1e3)
            m.histogram("kernel.pad_factor", op=op).observe(rec.pad_factor)
        self._notify(rec)

    # -- queries --------------------------------------------------------
    def ring(self, op: str) -> List[LaunchRecord]:
        return list(self._rings.get(op, ()))

    def ops(self) -> List[str]:
        return sorted(self._rings)


kernel_profiler = KernelProfiler()
