"""Observability for the serve/query stack: metrics, spans, kernel rings.

One :class:`Observability` bundle travels with a scheduler/engine pair:

* ``metrics`` — a :class:`~repro.obs.metrics.MetricsRegistry` (always
  on by default; counter cost matches the old ``stats`` dict it
  replaced),
* ``tracer`` — a :class:`~repro.obs.trace.Tracer` (off by default;
  enable with ``Observability(tracing=True)`` or ``obs.tracer.enabled
  = True``),
* ``profiler`` — the module-wide
  :data:`~repro.obs.profile.kernel_profiler` (off by default; scope it
  on with ``obs.profile_kernels()``).

The scheduler binds its clock seam into the tracer (``bind_clock``), so
``VirtualClock`` replays produce deterministic traces, and exports go
through :mod:`repro.obs.export` (Perfetto JSON + text metrics).
"""
from __future__ import annotations

from .export import (
    metrics_text,
    spans_to_trace_events,
    write_metrics,
    write_trace,
)
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    NULL_INSTRUMENT,
    format_metric,
    merge_snapshots,
    quantile_from_snapshot,
)
from .profile import KernelProfiler, LaunchRecord, kernel_profiler
from .trace import NULL_SPAN, Span, Tracer


class Observability:
    """Metrics + tracer + kernel profiler, bundled per serve component."""

    def __init__(self, metrics=None, tracer=None, *, tracing=False,
                 profiler=None):
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.tracer = tracer if tracer is not None else Tracer(enabled=tracing)
        self.profiler = profiler if profiler is not None else kernel_profiler

    def bind_clock(self, clock) -> None:
        """Point the tracer at a component's clock seam (first bind wins)."""
        if self.tracer.clock is None:
            self.tracer.clock = clock

    def profile_kernels(self):
        """Context manager: kernel-launch rings on, feeding ``metrics``."""
        return self.profiler.enabled_scope(metrics=self.metrics)

    def snapshot(self) -> dict:
        return self.metrics.snapshot()


__all__ = [
    "Observability",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "NULL_INSTRUMENT",
    "format_metric",
    "merge_snapshots",
    "quantile_from_snapshot",
    "Tracer",
    "Span",
    "NULL_SPAN",
    "KernelProfiler",
    "LaunchRecord",
    "kernel_profiler",
    "spans_to_trace_events",
    "write_trace",
    "metrics_text",
    "write_metrics",
]
