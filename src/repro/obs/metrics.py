"""Process-local metrics: counters, gauges, log-scale latency histograms.

The serve stack needs exact-ish p50/p99 over request latencies without
keeping every sample, per-tenant/per-op breakdowns without a metric
explosion, and snapshots that merge across schedulers (or across bench
replays) — all with hot-path cost comparable to the ad-hoc ``stats``
dict this module replaces (a dict lookup + an int add).

Design:

* Instruments are identified by ``(name, sorted(labels))``.  The
  registry get-or-creates on first touch and hands back the *instrument
  object*; callers that care about the hot path hold the instrument and
  call ``inc()`` directly instead of re-resolving labels per event.
* ``Histogram`` uses fixed geometric buckets (``lo * growth**i``) so two
  histograms with the same binning merge by adding count vectors.
  Quantiles interpolate geometrically inside the owning bucket and are
  clamped to the tracked ``[min, max]``, so the relative error of
  ``quantile(q)`` vs. an exact oracle is bounded by one bucket's growth
  factor (default ``2**0.25 ~ 1.19``) — tested against
  ``numpy.percentile`` in ``tests/test_obs.py``.
* A disabled registry hands out shared no-op instruments, so
  ``registry.counter("x").inc()`` costs two attribute lookups and
  nothing else.

Snapshots are plain JSON-able dicts (see ``snapshot`` / federated
``merge_snapshots``), rendered to text by ``repro.obs.export``.
"""
from __future__ import annotations

import math
from typing import Dict, Iterable, List, Optional, Tuple


LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Optional[dict]) -> LabelKey:
    if not labels:
        return ()
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def format_metric(name: str, key: LabelKey) -> str:
    """``name{k="v",...}`` — the stable text form used in snapshots."""
    if not key:
        return name
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return f"{name}{{{inner}}}"


class Counter:
    """Monotonic counter.  ``inc`` is the whole hot-path API."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins instantaneous value (queue depth, cache size)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: LabelKey = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def add(self, v: float) -> None:
        self.value += float(v)


class Histogram:
    """Fixed-bucket geometric histogram with quantile queries.

    Bucket ``0`` is the underflow bucket ``[0, lo)``; bucket ``i >= 1``
    covers ``[lo * growth**(i-1), lo * growth**i)``; the last bucket
    absorbs overflow.  Defaults cover 1e-3..1e7 (µs..hours when values
    are milliseconds) at ~19% relative resolution in 135 buckets.
    """

    __slots__ = ("name", "labels", "lo", "growth", "n_buckets", "counts",
                 "count", "total", "min", "max", "_log_g", "_log_lo")

    def __init__(
        self,
        name: str,
        labels: LabelKey = (),
        *,
        lo: float = 1e-3,
        hi: float = 1e7,
        growth: float = 2 ** 0.25,
    ):
        if not (lo > 0 and hi > lo and growth > 1):
            raise ValueError("need lo > 0, hi > lo, growth > 1")
        self.name = name
        self.labels = labels
        self.lo = float(lo)
        self.growth = float(growth)
        self._log_g = math.log(growth)
        self._log_lo = math.log(lo)
        # +1 for the underflow bucket, +1 so hi itself still lands inside
        self.n_buckets = int(math.ceil(math.log(hi / lo) / self._log_g)) + 2
        self.counts = [0] * self.n_buckets
        self.count = 0
        self.total = 0.0
        self.min = math.inf
        self.max = -math.inf

    # -- recording ------------------------------------------------------
    def _index(self, v: float) -> int:
        if v < self.lo:
            return 0
        i = int((math.log(v) - self._log_lo) / self._log_g) + 1
        return min(i, self.n_buckets - 1)

    def observe(self, v: float) -> None:
        v = float(v)
        if v < 0 or v != v:  # negative or NaN: count nothing, stay exact
            return
        self.counts[self._index(v)] += 1
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    # -- queries --------------------------------------------------------
    def _edges(self, i: int) -> Tuple[float, float]:
        if i == 0:
            return 0.0, self.lo
        return (self.lo * self.growth ** (i - 1), self.lo * self.growth ** i)

    def quantile(self, q: float) -> float:
        """Value at quantile ``q`` in [0, 1] (geometric interpolation)."""
        if self.count == 0:
            return 0.0
        q = min(max(q, 0.0), 1.0)
        target = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            if not c:
                continue
            cum += c
            if cum >= target:
                lo_e, hi_e = self._edges(i)
                frac = (target - (cum - c)) / c
                if lo_e <= 0.0:  # underflow bucket: linear interp
                    est = hi_e * frac
                else:
                    est = lo_e * (hi_e / lo_e) ** frac
                return min(max(est, self.min), self.max)
        return self.max  # pragma: no cover - cum always reaches count

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    # -- snapshots ------------------------------------------------------
    def snapshot(self) -> dict:
        return {
            "lo": self.lo,
            "growth": self.growth,
            "n_buckets": self.n_buckets,
            "counts": {str(i): c for i, c in enumerate(self.counts) if c},
            "count": self.count,
            "sum": self.total,
            "min": self.min if self.count else None,
            "max": self.max if self.count else None,
        }

    def merge_snapshot(self, snap: dict) -> None:
        """Fold a ``snapshot()`` dict (same binning) into this histogram."""
        if (snap["lo"], snap["growth"], snap["n_buckets"]) != (
            self.lo, self.growth, self.n_buckets
        ):
            raise ValueError("histogram binning mismatch; cannot merge")
        for i, c in snap["counts"].items():
            self.counts[int(i)] += c
        self.count += snap["count"]
        self.total += snap["sum"]
        if snap["min"] is not None:
            self.min = min(self.min, snap["min"])
        if snap["max"] is not None:
            self.max = max(self.max, snap["max"])


class _NullInstrument:
    """Shared sink for disabled registries: every method is a no-op."""

    __slots__ = ()
    name = ""
    labels: LabelKey = ()
    value = 0
    count = 0
    total = 0.0
    mean = 0.0

    def inc(self, n: int = 1) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def add(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def quantile(self, q: float) -> float:
        return 0.0


NULL_INSTRUMENT = _NullInstrument()


class MetricsRegistry:
    """Get-or-create home for every instrument in one process/component.

    ``enabled=False`` turns the registry into a sink: all factories
    return the shared :data:`NULL_INSTRUMENT` and nothing is recorded.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._counters: Dict[Tuple[str, LabelKey], Counter] = {}
        self._gauges: Dict[Tuple[str, LabelKey], Gauge] = {}
        self._histograms: Dict[Tuple[str, LabelKey], Histogram] = {}

    # -- factories (get-or-create) --------------------------------------
    def counter(self, name: str, labels: Optional[dict] = None, **kw):
        if not self.enabled:
            return NULL_INSTRUMENT
        key = (name, _label_key({**(labels or {}), **kw}))
        c = self._counters.get(key)
        if c is None:
            c = self._counters[key] = Counter(*key)
        return c

    def gauge(self, name: str, labels: Optional[dict] = None, **kw):
        if not self.enabled:
            return NULL_INSTRUMENT
        key = (name, _label_key({**(labels or {}), **kw}))
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = Gauge(*key)
        return g

    def histogram(
        self, name: str, labels: Optional[dict] = None, *, hist_kw=None, **kw
    ):
        if not self.enabled:
            return NULL_INSTRUMENT
        key = (name, _label_key({**(labels or {}), **kw}))
        h = self._histograms.get(key)
        if h is None:
            h = self._histograms[key] = Histogram(*key, **(hist_kw or {}))
        return h

    # -- queries --------------------------------------------------------
    def value(self, name: str, labels: Optional[dict] = None, **kw) -> float:
        """Counter/gauge value (0 if the instrument was never touched)."""
        key = (name, _label_key({**(labels or {}), **kw}))
        inst = self._counters.get(key) or self._gauges.get(key)
        return inst.value if inst is not None else 0

    def counters_named(self, name: str) -> List[Counter]:
        return [c for (n, _), c in self._counters.items() if n == name]

    def histograms_named(self, name: str) -> List[Histogram]:
        return [h for (n, _), h in self._histograms.items() if n == name]

    def label_values(self, name: str, label: str) -> List[str]:
        """Distinct values of ``label`` seen on instruments named ``name``."""
        out = set()
        for kind in (self._counters, self._gauges, self._histograms):
            for (n, key) in kind:
                if n == name:
                    out.update(v for k, v in key if k == label)
        return sorted(out)

    # -- snapshots ------------------------------------------------------
    def snapshot(self) -> dict:
        """JSON-able point-in-time view, keyed by the stable text form."""
        return {
            "counters": {
                format_metric(n, k): c.value
                for (n, k), c in sorted(self._counters.items())
            },
            "gauges": {
                format_metric(n, k): g.value
                for (n, k), g in sorted(self._gauges.items())
            },
            "histograms": {
                format_metric(n, k): h.snapshot()
                for (n, k), h in sorted(self._histograms.items())
            },
        }


def merge_snapshots(snaps: Iterable[dict]) -> dict:
    """Merge ``MetricsRegistry.snapshot()`` dicts: counters add, gauges
    last-write-wins, histograms add bucket vectors (same binning)."""
    out: dict = {"counters": {}, "gauges": {}, "histograms": {}}
    for snap in snaps:
        for k, v in snap.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0) + v
        out["gauges"].update(snap.get("gauges", {}))
        for k, h in snap.get("histograms", {}).items():
            if k not in out["histograms"]:
                out["histograms"][k] = {
                    **h, "counts": dict(h["counts"]),
                }
            else:
                acc = out["histograms"][k]
                if (acc["lo"], acc["growth"], acc["n_buckets"]) != (
                    h["lo"], h["growth"], h["n_buckets"]
                ):
                    raise ValueError(f"binning mismatch merging {k}")
                for i, c in h["counts"].items():
                    acc["counts"][i] = acc["counts"].get(i, 0) + c
                acc["count"] += h["count"]
                acc["sum"] += h["sum"]
                for f, pick in (("min", min), ("max", max)):
                    if h[f] is not None:
                        acc[f] = h[f] if acc[f] is None else pick(acc[f], h[f])
    return out


def quantile_from_snapshot(snap: dict, q: float) -> float:
    """Quantile query over a (possibly merged) histogram snapshot."""
    h = Histogram("_q", lo=snap["lo"], growth=snap["growth"],
                  hi=snap["lo"] * snap["growth"] ** (snap["n_buckets"] - 2))
    if h.n_buckets != snap["n_buckets"]:  # guard float edge in rebuild
        h.n_buckets = snap["n_buckets"]
        h.counts = [0] * h.n_buckets
    h.merge_snapshot(snap)
    return h.quantile(q)
