"""Exporters: Chrome/Perfetto ``trace_event`` JSON + plain-text metrics.

``spans_to_trace_events`` turns a tracer's span list into the Chrome
trace-event format that both ``chrome://tracing`` and
https://ui.perfetto.dev open directly: one ``ph: "X"`` (complete) event
per finished span, microsecond timestamps rebased to the earliest span,
chronologically ordered.  Spans are laid out on tracks (``tid``) by the
request that owns them — a span inherits the ``req`` attr from its
nearest annotated ancestor — so one Perfetto row shows a request's
``admit → queue → serve`` lifecycle while scheduler-step machinery
(``batch_form``, ``launch``, ``merge``) lives on the shared step track.

``metrics_text`` renders a ``MetricsRegistry`` (or a merged snapshot)
as one line per instrument — counters and gauges as ``name{labels} value``,
histograms with count/mean/p50/p99/max — greppable and diffable.
"""
from __future__ import annotations

import json
from typing import Dict, Iterable, List, Optional

from .metrics import MetricsRegistry, quantile_from_snapshot
from .trace import Span

_STEP_TID = 1          # scheduler-step machinery track
_REQ_TRACKS = 61       # request spans spread over this many tracks


def _tid_for(span: Span, by_id: Dict[int, Span]) -> int:
    """Track id: nearest ancestor carrying a ``req`` attr wins."""
    cur: Optional[Span] = span
    seen = 0
    while cur is not None and seen < 64:
        req = cur.attrs.get("req")
        if req is not None:
            return 2 + int(req) % _REQ_TRACKS
        cur = by_id.get(cur.parent_id)
        seen += 1
    return _STEP_TID


def spans_to_trace_events(
    spans: Iterable[Span],
    *,
    pid: int = 1,
    process_name: str = "repro-serve",
) -> dict:
    """Chrome ``trace_event`` JSON object (``json.dump``-ready)."""
    finished = [s for s in spans if s.end_s is not None]
    by_id = {s.span_id: s for s in finished}
    origin = min((s.start_s for s in finished), default=0.0)
    events: List[dict] = []
    tids = set()
    for s in finished:
        tid = _tid_for(s, by_id)
        tids.add(tid)
        events.append({
            "ph": "X",
            "name": s.name,
            "cat": str(s.attrs.get("op", "serve")),
            "pid": pid,
            "tid": tid,
            "ts": round((s.start_s - origin) * 1e6, 3),
            "dur": round((s.end_s - s.start_s) * 1e6, 3),
            "args": {
                "span_id": s.span_id,
                "parent_id": s.parent_id,
                **{k: _jsonable(v) for k, v in s.attrs.items()},
            },
        })
    events.sort(key=lambda e: (e["ts"], e["args"]["span_id"]))
    meta = [{
        "ph": "M", "name": "process_name", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    for tid in sorted(tids):
        label = ("scheduler steps" if tid == _STEP_TID
                 else f"requests %{_REQ_TRACKS} = {tid - 2}")
        meta.append({
            "ph": "M", "name": "thread_name", "pid": pid, "tid": tid,
            "args": {"name": label},
        })
    return {"traceEvents": meta + events, "displayTimeUnit": "ms"}


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


def write_trace(path: str, spans: Iterable[Span], **kw) -> dict:
    doc = spans_to_trace_events(spans, **kw)
    with open(path, "w") as f:
        json.dump(doc, f)
    return doc


def metrics_text(source) -> str:
    """Plain-text dump of a ``MetricsRegistry`` or a ``snapshot()`` dict."""
    snap = source.snapshot() if isinstance(source, MetricsRegistry) else source
    lines: List[str] = []
    for k, v in snap.get("counters", {}).items():
        lines.append(f"{k} {v}")
    for k, v in snap.get("gauges", {}).items():
        lines.append(f"{k} {v:g}")
    for k, h in snap.get("histograms", {}).items():
        if not h["count"]:
            lines.append(f"{k} count=0")
            continue
        p50 = quantile_from_snapshot(h, 0.50)
        p99 = quantile_from_snapshot(h, 0.99)
        lines.append(
            f"{k} count={h['count']} mean={h['sum'] / h['count']:.4g} "
            f"p50={p50:.4g} p99={p99:.4g} "
            f"min={h['min']:.4g} max={h['max']:.4g}"
        )
    return "\n".join(lines) + "\n"


def write_metrics(path: str, source) -> str:
    text = metrics_text(source)
    with open(path, "w") as f:
        f.write(text)
    return text
