"""Structured request spans for the serve/query stack.

A :class:`Span` is a named ``[start_s, end_s)`` interval with a parent
link and free-form attrs.  The :class:`Tracer` hands out span ids,
timestamps them from a pluggable monotonic clock (the scheduler binds
its own ``VirtualClock``/``MonotonicClock`` seam, so deterministic
replays produce bit-identical traces), and keeps finished + open spans
in one append-only list for export.

Two usage shapes:

* **Long-lived spans** (a request's ``request``/``queue`` spans live
  across many scheduler steps): ``start()`` / ``end()`` with an explicit
  ``parent``.  These do *not* touch the implicit current-span stack.
* **Scoped spans** (``batch_form``, ``launch``, engine-level spans):
  ``with tracer.span("launch", parent=step_span):``.  Scoped spans push
  themselves as the *current* span, so nested instrumentation deeper in
  the stack (``trie_engine``, ``resilience``) parents correctly without
  threading span objects through every signature.

Disabled tracers return the shared :data:`NULL_SPAN`; every operation
on it is a no-op, so the instrumented hot path pays one attribute check
when tracing is off.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class Span:
    name: str
    span_id: int
    parent_id: int  # -1 for roots
    start_s: float
    end_s: Optional[float] = None  # None while open
    attrs: Dict[str, Any] = field(default_factory=dict)

    @property
    def duration_s(self) -> float:
        return (self.end_s - self.start_s) if self.end_s is not None else 0.0


class _NullSpan:
    """Shared stand-in when tracing is disabled — absorbs everything."""

    __slots__ = ()
    name = ""
    span_id = -1
    parent_id = -1
    start_s = 0.0
    end_s = 0.0
    duration_s = 0.0

    @property
    def attrs(self) -> dict:
        return {}  # fresh throwaway; writes vanish by design


NULL_SPAN = _NullSpan()


class Tracer:
    """Span factory + sink.  Single-threaded by design (the serve loop
    is an event loop); ``spans`` is the export surface."""

    def __init__(self, enabled: bool = False, clock=None,
                 capacity: int = 1_000_000):
        self.enabled = enabled
        self.clock = clock  # needs .now() -> seconds; None = monotonic
        self.capacity = capacity
        self.spans: List[Span] = []
        self.dropped = 0
        self._next_id = 0
        self._stack: List[Span] = []  # scoped spans only

    # -- time -----------------------------------------------------------
    def now(self) -> float:
        return self.clock.now() if self.clock is not None else time.monotonic()

    # -- span lifecycle -------------------------------------------------
    def start(self, name: str, parent=None, **attrs):
        """Open a span.  ``parent`` may be a Span, ``None`` (inherit the
        current scoped span, or root if none), or ``False`` (force root)."""
        if not self.enabled:
            return NULL_SPAN
        if len(self.spans) >= self.capacity:
            self.dropped += 1
            return NULL_SPAN
        if parent is None:
            parent = self._stack[-1] if self._stack else None
        pid = parent.span_id if parent else -1
        sp = Span(name, self._next_id, pid, self.now(), None, attrs)
        self._next_id += 1
        self.spans.append(sp)
        return sp

    def end(self, span, **attrs) -> None:
        if span is None or span is NULL_SPAN:
            return
        if attrs:
            span.attrs.update(attrs)
        if span.end_s is None:
            span.end_s = self.now()

    def annotate(self, span, **attrs) -> None:
        if span is not None and span is not NULL_SPAN:
            span.attrs.update(attrs)

    @contextmanager
    def span(self, name: str, parent=None, **attrs):
        """Scoped span: pushed as the implicit current parent."""
        sp = self.start(name, parent=parent, **attrs)
        if sp is NULL_SPAN:
            yield sp
            return
        self._stack.append(sp)
        try:
            yield sp
        except BaseException as exc:
            sp.attrs.setdefault("error", type(exc).__name__)
            raise
        finally:
            self._stack.pop()
            self.end(sp)

    # -- export helpers -------------------------------------------------
    def finished(self) -> List[Span]:
        return [s for s in self.spans if s.end_s is not None]

    def clear(self) -> None:
        self.spans.clear()
        self._stack.clear()
        self.dropped = 0
