import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first initialization, and the dry-run (and only
the dry-run) needs 512 placeholder host devices to build the production
meshes.  Tests and benchmarks import nothing from here and see 1 device.

Per cell this script:
  1. builds the production mesh (16×16 or 2×16×16),
  2. lowers the right step (train_step / prefill / decode) against
     ShapeDtypeStruct inputs (no allocation — a 671B model lowers fine),
  3. compiles, prints ``memory_analysis()`` and ``cost_analysis()``,
  4. extracts per-device collective bytes from the optimized HLO,
  5. appends a JSON record under experiments/dryrun/.

Usage:
  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--arch-filter moe]
"""
import argparse
import json
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_shape, SHAPES
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import (
    batch_specs,
    cache_specs,
    input_specs,
    param_specs,
    shard_tree,
)
from repro.serve.engine import make_decode_step, make_prefill_step
from repro.train.optimizer import pick_optimizer
from repro.train.train_step import make_train_step

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# HLO collective ops and their ring wire-cost multipliers (× output bytes)
_COLLECTIVE_RE = re.compile(
    r"=\s*(\S+)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_MULT = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}


def _shape_bytes(sig: str) -> int:
    """Bytes of the (possibly tuple) result shape on the lhs of an op."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(sig):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


# ops that materialize HBM traffic on TPU even under XLA fusion
_BOUNDARY_OPS = (
    "dot", "convolution", "reduce", "reduce-window", "scatter", "gather",
    "sort", "dynamic-update-slice", "dynamic-slice", "transpose", "copy",
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "while", "iota",
)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|\S+))\s+([\w\-]+)"
)
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def boundary_bytes(hlo_text: str) -> float:
    """Fusion-boundary HBM-traffic estimate (per device).

    Counts result bytes of every op whose output materializes on TPU
    (matmuls, reductions, data movement, collectives) plus the operand
    bytes of dots/convolutions (their inputs are read from HBM), and the
    program arguments once.  Elementwise/broadcast chains are assumed
    fused away — this is the *TPU-style* counterpart of the CPU cost
    analysis' unfused "bytes accessed" upper bound.
    """
    sizes: Dict[str, int] = {}
    lines = hlo_text.splitlines()
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            sizes[m.group(1)] = _shape_bytes(m.group(2))
    total = 0.0
    for line in lines:
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, sig, op = m.groups()
        if op == "parameter":
            total += sizes.get(name, 0)
            continue
        if not any(op == b or op.startswith(b) for b in _BOUNDARY_OPS):
            continue
        if op == "while":
            continue  # body ops counted individually
        total += sizes.get(name, 0)
        if op in ("dot", "convolution"):
            # read both operands from HBM
            tail = line.split("(", 1)[-1]
            ops = _OPERAND_RE.findall(tail.split(")")[0])
            for o in ops:
                total += sizes.get(o, 0)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device collective traffic by op type (output-bytes × ring mult)."""
    out: Dict[str, float] = {k: 0.0 for k in _MULT}
    for line in hlo_text.splitlines():
        m = _COLLECTIVE_RE.search(line)
        if not m:
            continue
        sig, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(sig) * _MULT[kind]
    out["total"] = sum(out.values())
    return out


def _mem_dict(mem) -> Dict[str, float]:
    keys = (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "generated_code_size_in_bytes",
        "alias_size_in_bytes",
    )
    out = {}
    for k in keys:
        out[k] = float(getattr(mem, k, 0.0) or 0.0)
    return out


def _lower_cell(cfg, shape, mesh, opt=None, seq_override=None):
    """Lower the right step kind for (cfg, shape) on ``mesh``.

    ``seq_override`` shrinks the *token* sequence (cost-measurement mode)
    while the prefill cache keeps the cell's true length, so the
    attention kv extent stays authentic."""
    import dataclasses as _dc

    aparams, axes = param_specs(cfg, mesh)
    tok_shape = (
        _dc.replace(shape, seq_len=seq_override) if seq_override else shape
    )
    if shape.kind == "train":
        if opt is None:
            opt = pick_optimizer(cfg)
        opt_sds = jax.eval_shape(opt.init, aparams)
        opt_sharded = shard_tree(opt_sds, opt.state_axes(axes), mesh)
        step = make_train_step(cfg, opt)
        batch = batch_specs(cfg, tok_shape, mesh, with_labels=True)
        step_idx = jax.ShapeDtypeStruct((), jnp.float32)
        return jax.jit(step, donate_argnums=(0, 1)).lower(
            aparams, opt_sharded, batch, step_idx
        )
    if shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        batch = batch_specs(cfg, tok_shape, mesh, with_labels=False)
        cache = cache_specs(
            cfg, mesh, shape.global_batch,
            shape.seq_len
            + (cfg.frontend_len if cfg.frontend != "none" else 0),
        )
        return jax.jit(fn, donate_argnums=(1,)).lower(
            aparams, cache, batch
        )
    fn = make_decode_step(cfg)
    cache = cache_specs(cfg, mesh, shape.global_batch, shape.seq_len)
    tokens = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    return jax.jit(fn, donate_argnums=(1,)).lower(aparams, cache, tokens)


def _costs(compiled) -> Dict[str, Any]:
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "bytes_boundary": boundary_bytes(hlo),
        "collectives": collective_bytes(hlo),
    }


def run_cell(
    arch: str, shape_name: str, multi_pod: bool, transform=None
) -> Dict[str, Any]:
    cfg = get_config(arch)
    if transform is not None:
        cfg = transform(cfg)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    record: Dict[str, Any] = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "kind": shape.kind,
        "n_devices": mesh.devices.size,
    }
    with jax.set_mesh(mesh):
        # 1) the PRODUCTION lowering (scan + remat): proves compile +
        #    gives the true memory picture.
        t0 = time.time()
        lowered = _lower_cell(cfg, shape, mesh)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
        mem = compiled.memory_analysis()
        print(mem)

        # 2) cost measurement.  XLA counts `while` bodies once, so we
        #    lower UNROLLED variants and extrapolate.  Every HLO op size
        #    is polynomial (degree ≤2) in the chunk count c (attention is
        #    quadratic in c for train where kv extent = tokens, affine
        #    for prefill where kv = fixed cache) and affine in the unit
        #    count u, so  cost(u, c) = a + b·u + d·c + e·uc + g·c² + h·uc²
        #    is EXACT; six measurements (u∈{1,2} × c∈{2,3,4}) determine
        #    it and we evaluate at the cell's true (n_units, n_chunks).
        #    Decode cells (s=1, no chunk loop) use the 2-point u form.
        opt = pick_optimizer(cfg) if shape.kind == "train" else None
        q_chunk = 256
        if shape.kind in ("train", "prefill"):
            us, cs = (1, 2), (2, 3, 4)
            meas = {}
            for u in us:
                cfg_u = cfg.scaled(n_units=u, unroll_scans=True)
                for c in cs:
                    low = _lower_cell(
                        cfg_u, shape, mesh, opt=opt,
                        seq_override=c * q_chunk,
                    )
                    meas[(u, c)] = _costs(low.compile())
            true_c = shape.seq_len / q_chunk
            costs = _poly_extrapolate(
                meas, cfg.n_units, true_c,
                quadratic=(shape.kind == "train"),
            )
            record["raw_measurements"] = {
                f"u{u}c{c}": meas[(u, c)] for (u, c) in meas
            }
        else:
            cfg_a = cfg.scaled(n_units=1, unroll_scans=True)
            cfg_b = cfg.scaled(n_units=2, unroll_scans=True)
            ca = _costs(_lower_cell(cfg_a, shape, mesh, opt=opt).compile())
            cb = _costs(_lower_cell(cfg_b, shape, mesh, opt=opt).compile())
            n = cfg.n_units
            costs = {
                "flops": ca["flops"] + (n - 1) * (cb["flops"] - ca["flops"]),
                "bytes": ca["bytes"] + (n - 1) * (cb["bytes"] - ca["bytes"]),
                "bytes_boundary": ca["bytes_boundary"]
                + (n - 1) * (cb["bytes_boundary"] - ca["bytes_boundary"]),
                "collectives": {
                    k: ca["collectives"][k]
                    + (n - 1) * (cb["collectives"][k] - ca["collectives"][k])
                    for k in ca["collectives"]
                },
            }
        t3 = time.time()

    record.update(
        {
            "lower_seconds": t1 - t0,
            "compile_seconds": t2 - t1,
            "cost_measure_seconds": t3 - t2,
            "memory": _mem_dict(mem),
            "flops_per_device": costs["flops"],
            "bytes_per_device": costs["bytes"],
            "bytes_boundary_per_device": costs["bytes_boundary"],
            "collective_bytes_per_device": costs["collectives"],
        }
    )
    print({k: record[k] for k in ("flops_per_device", "bytes_per_device")})
    return record


def _poly_extrapolate(
    meas, n_units: int, true_c: float, quadratic: bool = True
) -> Dict[str, Any]:
    """Solve cost(u,c) = a + b·u + d·c + e·uc [+ g·c² + h·uc²] from the
    (u, c) measurements and evaluate at (n_units, true_c).

    The c² terms exist only for train cells (attention kv extent = token
    count); prefill/decode kv extents are fixed by the cache, so fitting
    the affine basis avoids ill-conditioned extrapolation of a spurious
    quadratic coefficient to c≈128."""
    import numpy as np

    keys = sorted(meas)
    if quadratic:
        basis = lambda u, c: [1.0, u, c, u * c, c * c, u * c * c]
    else:
        basis = lambda u, c: [1.0, u, c, u * c]
    m = np.array([basis(u, c) for (u, c) in keys])
    target = np.array(basis(n_units, true_c))

    def solve(values):
        coef, *_ = np.linalg.lstsq(m, np.array(values), rcond=None)
        return float(np.maximum(target @ coef, 0.0))

    out = {
        "flops": solve([meas[k]["flops"] for k in keys]),
        "bytes": solve([meas[k]["bytes"] for k in keys]),
        "bytes_boundary": solve(
            [meas[k]["bytes_boundary"] for k in keys]
        ),
    }
    coll_keys = meas[keys[0]]["collectives"].keys()
    out["collectives"] = {
        ck: solve([meas[k]["collectives"][ck] for k in keys])
        for ck in coll_keys
    }
    return out


def long_500k_applicable(arch: str) -> bool:
    """long_500k is a decode cell: linear in KV even for full attention,
    so every arch runs it (DESIGN.md §5)."""
    return True


def recost(out_dir: str):
    """Update existing cell records with the current cost estimators
    (bytes_boundary etc.) without redoing the production compile."""
    import glob

    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rec = json.load(f)
        if "bytes_boundary_per_device" in rec:
            continue
        print(f"[recost] {os.path.basename(path)}", flush=True)
        try:
            fresh = run_cell(
                rec["arch"], rec["shape"], rec["mesh"] == "pod2x16x16"
            )
        except Exception:
            traceback.print_exc()
            continue
        # keep the original compile proof / memory; refresh cost fields
        for k in (
            "flops_per_device", "bytes_per_device",
            "bytes_boundary_per_device", "collective_bytes_per_device",
            "raw_measurements", "cost_measure_seconds",
        ):
            if k in fresh:
                rec[k] = fresh[k]
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
    print("recost done")


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--all", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--recost", action="store_true")
    p.add_argument("--out", default=OUT_DIR)
    args = p.parse_args()

    if args.recost:
        recost(args.out)
        return

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        meshes = [False, True] if args.both_meshes else [args.multi_pod]
        for arch in ARCH_IDS:
            for shape in SHAPES:
                for mp in meshes:
                    cells.append((arch, shape.name, mp))
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    failures = []
    for arch, shape, mp in cells:
        tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
        path = os.path.join(args.out, tag + ".json")
        if os.path.exists(path):
            print(f"[skip] {tag} (exists)")
            continue
        print(f"[cell] {tag}", flush=True)
        try:
            rec = run_cell(arch, shape, mp)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(
                f"[ok] {tag}: compile={rec['compile_seconds']:.1f}s "
                f"flops/dev={rec['flops_per_device']:.3e} "
                f"coll/dev={rec['collective_bytes_per_device']['total']:.3e}",
                flush=True,
            )
        except Exception:
            failures.append(tag)
            with open(path + ".err", "w") as f:
                traceback.print_exc(file=f)
            traceback.print_exc()
    if failures:
        print("FAILED CELLS:", failures)
        raise SystemExit(1)
    print("all cells ok")


if __name__ == "__main__":
    main()
