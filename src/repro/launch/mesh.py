"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (never module-level state) so that
importing this module never touches jax device initialization.  The
single-pod mesh is 16×16 = 256 chips (data, model); the multi-pod mesh is
2×16×16 = 512 chips (pod, data, model) — the ``pod`` axis carries
inter-pod data parallelism (DCN-grade collectives only: gradient
all-reduce), while ``model`` stays intra-pod on ICI.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly Auto everywhere
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh() -> Mesh:
    """Degenerate 1×1 mesh over the real local device (tests, examples)."""
    n = jax.device_count()
    return _make_mesh((1, n), ("data", "model"))


def make_trie_mesh(n_shards: int | None = None) -> Mesh:
    """1-D ``("data",)`` mesh for the sharded Trie-of-Rules engine.

    The frozen trie partitions into contiguous DFS subtree ranges, one per
    device along the single ``data`` axis (``distributed.trie_sharding``);
    there is no model axis — queries replicate, the STRUCTURE shards.
    ``n_shards`` defaults to every visible device; pass less to shard over
    a device prefix (benchmark P-sweeps, CI with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).
    """
    n = jax.device_count() if n_shards is None else int(n_shards)
    if not 1 <= n <= jax.device_count():
        raise ValueError(
            f"n_shards={n} outside [1, {jax.device_count()}] "
            "visible devices"
        )
    if AxisType is not None:
        return Mesh(
            np.array(jax.devices()[:n]), ("data",),
            axis_types=(AxisType.Auto,),
        )
    return Mesh(np.array(jax.devices()[:n]), ("data",))
