"""Launch layer: meshes, dry-run, roofline, train driver.

NOTE: never import ``dryrun`` transitively from here — it sets XLA_FLAGS
for 512 host devices at import time, which must only happen in a dedicated
process.
"""
from .mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh"]
