"""ShapeDtypeStruct input stand-ins for every (arch × shape) dry-run cell.

``input_specs`` returns weak-type-correct, shardable ShapeDtypeStructs —
never device arrays — for the three step kinds:

  train    {tokens, labels [B, S]} (+ frontend_embeds stub)
  prefill  {tokens [B, S]} + empty decode cache (prefill populates it)
  decode   {tokens [B, 1]} + a full-length decode cache

Sharding is attached to each struct from the logical-axis rules so
``jit(...).lower(**specs)`` sees the production layout.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import (
    LOGICAL_RULES,
    SERVING_RULES,
    logical_to_spec,
)
from repro.models import abstract_params, cache_axes, init_cache


def _sds(shape, dtype, mesh, axes, rules=None):
    sharding = NamedSharding(
        mesh, logical_to_spec(axes, mesh, shape, rules=rules)
    )
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def shard_tree(tree, axes_tree, mesh: Mesh, rules=None):
    """ShapeDtypeStruct tree + logical-axes tree → sharded SDS tree."""
    return jax.tree.map(
        lambda sds, axes: _sds(sds.shape, sds.dtype, mesh, axes, rules),
        tree,
        axes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def batch_specs(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh, with_labels: bool
) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    out = {
        "tokens": _sds((b, s), jnp.int32, mesh, ("batch", "seq")),
    }
    if with_labels:
        out["labels"] = _sds((b, s), jnp.int32, mesh, ("batch", "seq"))
    if cfg.frontend != "none" and shape.kind != "decode":
        out["frontend_embeds"] = _sds(
            (b, cfg.frontend_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype), mesh,
            ("batch", "seq", "embed"),
        )
    return out


def param_specs(cfg: ModelConfig, mesh: Mesh):
    params, axes = abstract_params(cfg)
    if cfg.serving:
        rules = dict(SERVING_RULES)
        if not cfg.serve_expert_ff_tp:
            rules["expert_ff"] = None   # replicate expert slices instead
    else:
        rules = LOGICAL_RULES
    return shard_tree(params, axes, mesh, rules=rules), axes


def cache_specs(cfg: ModelConfig, mesh: Mesh, batch: int, max_seq: int):
    cache = jax.eval_shape(
        lambda: init_cache(cfg, batch, max_seq, jnp.bfloat16)
    )
    axes = cache_axes(cfg)

    def fix(sds, ax):
        ax = tuple(ax)
        if len(ax) < len(sds.shape):  # scalar 'pos' entries etc.
            ax = ax + (None,) * (len(sds.shape) - len(ax))
        return _sds(sds.shape, sds.dtype, mesh, ax)

    return jax.tree.map(
        fix, cache, axes,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def input_specs(
    cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
) -> Dict[str, Any]:
    """All lowering inputs for one dry-run cell (excl. params/opt)."""
    if shape.kind == "train":
        return {"batch": batch_specs(cfg, shape, mesh, with_labels=True)}
    if shape.kind == "prefill":
        return {
            "batch": batch_specs(cfg, shape, mesh, with_labels=False),
            "cache": cache_specs(
                cfg, mesh, shape.global_batch,
                shape.seq_len + (cfg.frontend_len
                                 if cfg.frontend != "none" else 0),
            ),
        }
    if shape.kind == "decode":
        return {
            "tokens": _sds(
                (shape.global_batch, 1), jnp.int32, mesh,
                ("batch", "seq"),
            ),
            "cache": cache_specs(
                cfg, mesh, shape.global_batch, shape.seq_len
            ),
        }
    raise ValueError(shape.kind)
