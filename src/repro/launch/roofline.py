"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) cell, from the dry-run JSON records:

    compute term    = flops_per_device / peak_FLOPs
    memory term     = bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(The dry-run compiled module is the per-device SPMD program, so the
recorded numbers are already per-chip; dividing global quantities by chip
count gives the same terms.)  The dominant term is the bottleneck; the
MODEL_FLOPS ratio (6·N·D for dense, 6·N_active·D for MoE) measures how
much compiled compute is "useful".

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

# Per-backend peaks for the KERNEL bench lanes' achieved-vs-peak figure
# (``kernel_roofline``).  The TPU row is the v5e chip above; the cpu row
# is a deliberately conservative dual-channel DDR4 envelope (~25.6 GB/s)
# so interpret-mode utilization figures read as what they are — Python
# emulation, nowhere near the roof.
KERNEL_PEAKS = {
    "tpu": {"peak_flops": PEAK_FLOPS, "hbm_gbps": HBM_BW / 1e9},
    "gpu": {"peak_flops": 989e12, "hbm_gbps": 3350.0},   # H100 SXM bf16
    "cpu": {"peak_flops": 1e12, "hbm_gbps": 25.6},
}

DRYRUN_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"
)


def kernel_roofline(
    bytes_moved: float, seconds: float, backend: Optional[str] = None
) -> Dict:
    """Achieved-vs-peak bandwidth for one kernel bench lane.

    ``bytes_moved`` is the lane's streamed working set per call (the
    trie kernels are memory-bound column sweeps, so bytes/peak-BW is the
    relevant roof); ``seconds`` the measured per-call time.  Returns the
    achieved GB/s, the backend's peak, and their ratio — the
    bandwidth-utilization figure the bench reports emit next to each
    speedup ratio.  Unknown backends fall back to the cpu envelope.
    """
    if backend is None:
        import jax

        backend = jax.default_backend()
    peaks = KERNEL_PEAKS.get(backend, KERNEL_PEAKS["cpu"])
    achieved = (bytes_moved / seconds) / 1e9 if seconds > 0 else 0.0
    peak = peaks["hbm_gbps"]
    return {
        "backend": backend,
        "bytes_moved": float(bytes_moved),
        "seconds": float(seconds),
        "achieved_gbps": achieved,
        "peak_gbps": peak,
        "bandwidth_util": achieved / peak if peak > 0 else 0.0,
    }


def model_flops(arch: str, shape_name: str) -> float:
    """6·N_active·D (training) / 2·N_active·D (single forward)."""
    from repro.configs import get_config, get_shape
    from repro.models import count_params_analytic

    cfg = get_config(arch)
    shape = get_shape(shape_name)
    n_active = count_params_analytic(cfg, active_only=True)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens


def analyze_record(rec: Dict, use_boundary: bool = False) -> Dict:
    """use_boundary=True picks the fusion-boundary memory estimate when
    recorded; the main table uses the unfused upper bound uniformly (all
    80 baseline cells share that estimator)."""
    flops = rec["flops_per_device"]
    mem_bytes = rec["bytes_per_device"]
    if use_boundary:
        mem_bytes = rec.get("bytes_boundary_per_device", mem_bytes)
    coll = rec["collective_bytes_per_device"]["total"]
    chips = rec["n_devices"]
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": mem_bytes / HBM_BW,
        "memory_unfused_s": rec["bytes_per_device"] / HBM_BW,
        "collective_s": coll / LINK_BW,
    }
    core = {k: terms[k] for k in
            ("compute_s", "memory_s", "collective_s")}
    dominant = max(core, key=core.get)
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_global = flops * chips
    bound = max(core.values())
    useful_s = (mf / chips) / PEAK_FLOPS
    out = dict(rec)
    out.update(
        {
            "terms": terms,
            "dominant": dominant,
            "model_flops_global": mf,
            "hlo_flops_global": hlo_global,
            "useful_ratio": mf / hlo_global if hlo_global else 0.0,
            # fraction of the compute roofline actually achievable given
            # the dominant bound: useful-model-time / bound-time
            "roofline_fraction": useful_s / bound if bound else 0.0,
        }
    )
    return out


def load_records(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def what_moves_it(rec: Dict) -> str:
    d = rec["dominant"]
    if d == "compute_s":
        if rec["useful_ratio"] < 0.4:
            return (
                "compute-bound with low useful ratio: cut non-model flops "
                "(causal chunk skipping, less remat recompute)"
            )
        return "compute-bound: larger per-chip batch or more chips"
    if d == "memory_s":
        return (
            "HBM-bound: fuse/shrink intermediates (bf16 scores, fewer "
            "materialized masks), increase arithmetic intensity"
        )
    return (
        "collective-bound: shrink collective payloads (bf16 psum, "
        "reduce-scatter instead of all-reduce) or overlap with compute"
    )


def table(records: List[Dict], mesh: Optional[str] = "pod16x16") -> str:
    rows = []
    header = (
        "| arch | shape | mesh | compute s | memory s | collective s | "
        "dominant | useful | roofline frac |"
    )
    rows.append(header)
    rows.append("|" + "---|" * 9)
    for rec in records:
        if mesh and rec["mesh"] != mesh:
            continue
        a = analyze_record(rec)
        t = a["terms"]
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['mesh']} "
            f"| {t['compute_s']:.3e} | {t['memory_s']:.3e} "
            f"| {t['collective_s']:.3e} | {a['dominant'][:-2]} "
            f"| {a['useful_ratio']:.2f} | {a['roofline_fraction']:.3f} |"
        )
    return "\n".join(rows)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--dir", default=DRYRUN_DIR)
    p.add_argument("--mesh", default=None,
                   help="pod16x16 | pod2x16x16 | None=all")
    args = p.parse_args()
    records = load_records(args.dir)
    print(table(records, args.mesh))
    print()
    for rec in records:
        if args.mesh and rec["mesh"] != args.mesh:
            continue
        a = analyze_record(rec)
        print(
            f"{a['arch']} × {a['shape']} × {a['mesh']}: "
            f"{a['dominant'][:-2]}-bound — {what_moves_it(a)}"
        )


if __name__ == "__main__":
    main()
