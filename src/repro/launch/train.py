"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train \
        --arch smollm-360m --reduced --steps 200 --resume auto

Features exercised here (the large-scale runnability story, scaled to the
local device):
  - deterministic stateless-seekable data pipeline (restart = same stream)
  - async, atomic, hash-verified checkpoints + auto-resume
  - straggler detection (EWMA step times) with an elastic re-mesh hook
  - microbatch gradient accumulation (collective/compute overlap knob)
  - optional int8 error-feedback gradient compression
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced_config
from repro.data.pipeline import PipelineConfig, TokenPipeline, synthetic_corpus
from repro.distributed.compression import ErrorFeedbackInt8
from repro.launch.mesh import make_host_mesh
from repro.models import materialize_params
from repro.train.checkpoint import AsyncCheckpointer, restore_latest
from repro.train.elastic import StragglerDetector
from repro.train.optimizer import OptConfig, pick_optimizer
from repro.train.train_step import make_train_step


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-360m")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--steps", type=int, default=200)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=3e-4)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--compress-grads", action="store_true")
    p.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    p.add_argument("--ckpt-every", type=int, default=50)
    p.add_argument("--resume", default="auto", choices=["auto", "never"])
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args()

    cfg = (
        get_reduced_config(args.arch) if args.reduced
        else get_config(args.arch)
    )
    # fit the byte vocab when training on the synthetic corpus
    cfg = cfg.scaled(vocab_size=max(cfg.vocab_size, 260))

    mesh = make_host_mesh()
    docs = synthetic_corpus(512, seed=1)
    pipe = TokenPipeline(
        docs, PipelineConfig(seq_len=args.seq, global_batch=args.batch)
    )
    print(f"pipeline: {pipe.n_rows} packed rows")

    with jax.set_mesh(mesh):
        params, axes = materialize_params(cfg, jax.random.PRNGKey(0))
        opt = pick_optimizer(cfg, OptConfig(lr=args.lr, warmup_steps=20))
        opt_state = opt.init(params)

        compressor = ErrorFeedbackInt8() if args.compress_grads else None
        residual = compressor.init(params) if compressor else None

        grad_transform = None
        if compressor is not None:
            # stateful hook: closure carries the residual across steps
            state = {"residual": residual}

            def grad_transform(grads):
                dq, state["residual"] = compressor.compress(
                    grads, state["residual"]
                )
                return dq

        step_fn = jax.jit(
            make_train_step(
                cfg, opt, microbatches=args.microbatches,
                grad_transform=grad_transform,
            ),
            donate_argnums=(0, 1),
        )

        start_step = 0
        ckpt = AsyncCheckpointer(args.ckpt_dir)
        if args.resume == "auto":
            restored, manifest = restore_latest(
                args.ckpt_dir, {"params": params, "opt": opt_state}
            )
            if restored is not None:
                params = jax.tree.map(jnp.asarray, restored["params"])
                opt_state = jax.tree.map(jnp.asarray, restored["opt"])
                start_step = manifest["step"] + 1
                print(f"resumed from step {manifest['step']}")

        detector = StragglerDetector()
        losses = []
        for step in range(start_step, args.steps):
            batch = {
                k: jnp.asarray(v) for k, v in pipe.batch_at(step).items()
            }
            t0 = time.time()
            params, opt_state, metrics = step_fn(
                params, opt_state, batch, jnp.float32(step)
            )
            loss = float(metrics["loss"])
            dt = time.time() - t0
            losses.append(loss)
            if detector.observe(step, dt):
                print(f"[straggler] sustained slowdown at step {step} "
                      f"({dt:.2f}s vs ewma {detector._ewma:.2f}s) — a real "
                      "deployment re-meshes here (train/elastic.py)")
            if step % args.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"({dt*1000:.0f} ms)", flush=True)
            if step and step % args.ckpt_every == 0:
                ckpt.save_async(
                    step, {"params": params, "opt": opt_state},
                    extra={"loss": loss},
                )
        ckpt.wait()
        ckpt.save_async(args.steps - 1,
                        {"params": params, "opt": opt_state})
        ckpt.wait()
        first = np.mean(losses[:10])
        last = np.mean(losses[-10:])
        print(f"done: loss {first:.3f} → {last:.3f} "
              f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()
