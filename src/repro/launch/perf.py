"""§Perf hillclimb driver: re-lower a dry-run cell under optimization
variants and report the three roofline terms per variant.

    PYTHONPATH=src python -m repro.launch.perf \
        --arch smollm-360m --shape train_4k \
        --variants baseline,causal_skip

Each variant is a pure ModelConfig transformation (the baseline is the
paper-faithful configuration the main sweep used), so before/after deltas
are apples-to-apples on the same cost estimator.
"""
import argparse
import json
import os
from typing import Callable, Dict

from repro.configs.base import ModelConfig

VARIANTS: Dict[str, Callable[[ModelConfig], ModelConfig]] = {
    "baseline": lambda c: c,
    # compute/memory: visit only the causal triangle of kv blocks
    "causal_skip": lambda c: c.scaled(causal_skip=True),
    # memory/compute tradeoff: save matmul outputs instead of recomputing
    "remat_dots": lambda c: c.scaled(remat_policy="dots"),
    # collective: serving layout — no FSDP weight gathers, expert-TP,
    # bf16 weights
    "serve_layout": lambda c: c.scaled(
        serving=True, param_dtype="bfloat16"
    ),
    # collective: serving layout + bf16 MoE psum payloads
    "serve_layout+psum_bf16": lambda c: c.scaled(
        serving=True, param_dtype="bfloat16", moe_psum_bf16=True
    ),
    # combined training recipe
    "causal_skip+remat_dots": lambda c: c.scaled(
        causal_skip=True, remat_policy="dots"
    ),
    # training collective: bf16 MoE psum only
    "psum_bf16": lambda c: c.scaled(moe_psum_bf16=True),
    # prefill recipe: serve weight layout (no FSDP gathers) but tokens
    # stay local (train-style EP); experts replicated over data
    "serve_weights": lambda c: c.scaled(
        serving=True, param_dtype="bfloat16", serve_expert_ff_tp=False
    ),
    "serve_weights+psum_bf16": lambda c: c.scaled(
        serving=True, param_dtype="bfloat16", serve_expert_ff_tp=False,
        moe_psum_bf16=True,
    ),
    "serve_weights+psum_bf16+causal_skip": lambda c: c.scaled(
        serving=True, param_dtype="bfloat16", serve_expert_ff_tp=False,
        moe_psum_bf16=True, causal_skip=True,
    ),
    # smaller attention working set
    "causal_skip+psum_bf16": lambda c: c.scaled(
        causal_skip=True, moe_psum_bf16=True
    ),
}


def main():
    # The 512-host-device mesh must be requested before jax initializes —
    # set here (not at module import) so merely importing this module
    # (tests, the bench harness) never mutates the process's device count.
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
    )
    from repro.launch.dryrun import run_cell
    from repro.launch.roofline import PEAK_FLOPS, HBM_BW, LINK_BW

    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--variants", default="baseline")
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", default=None)
    args = p.parse_args()

    rows = []
    for name in args.variants.split(","):
        transform = VARIANTS[name]
        rec = run_cell(args.arch, args.shape, args.multi_pod, transform)
        terms = {
            "compute_s": rec["flops_per_device"] / PEAK_FLOPS,
            "memory_s": rec["bytes_per_device"] / HBM_BW,
            "memory_boundary_s":
                rec.get("bytes_boundary_per_device", 0.0) / HBM_BW,
            "collective_s":
                rec["collective_bytes_per_device"]["total"] / LINK_BW,
        }
        core = {k: terms[k] for k in
                ("compute_s", "memory_s", "collective_s")}
        dom = max(core, key=core.get)
        rows.append((name, terms, dom, rec))
        print(
            f"[{name}] compute={terms['compute_s']:.3e}s "
            f"memory={terms['memory_s']:.3e}s "
            f"memory_boundary={terms['memory_boundary_s']:.3e}s "
            f"collective={terms['collective_s']:.3e}s "
            f"dominant={dom} "
            f"temp_mem={rec['memory']['temp_size_in_bytes']/2**30:.1f}GiB",
            flush=True,
        )

    if len(rows) > 1:
        base = rows[0][1]
        for name, terms, dom, _ in rows[1:]:
            print(f"\n{name} vs {rows[0][0]}:")
            for k in terms:
                if base[k] > 0:
                    print(f"  {k}: {base[k]:.3e} → {terms[k]:.3e} "
                          f"({terms[k]/base[k]:.2%})")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(
                [{"variant": n, "terms": t, "dominant": d,
                  "record": r} for n, t, d, r in rows],
                f, indent=1,
            )


if __name__ == "__main__":
    main()
